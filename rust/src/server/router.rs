//! Multi-card router: load-balances inference requests over a fleet of
//! serving [`Engine`]s in virtual time.
//!
//! Policies: round-robin, least-loaded (join-shortest-queue), and a
//! power-of-two-choices sampler — the standard serving trade-off space.
//!
//! Since PR 3 the router runs a **continuous batcher per card**
//! ([`CardBatcher`], the same batch-formation core the wall-clock
//! executor uses): a routed request joins its card's bounded queue, the
//! card forms 8/4/2/1-bucket launches under per-class SLO deadlines
//! ([`SloPolicy`]), and the load signal the JSQ policies compare is the
//! **modelled backlog** — the card's residual busy time plus its queued
//! requests priced through [`decompose`] + [`Engine::service_estimate`]
//! ([`LoadModel::Backlog`]). The pre-batcher signal (raw busy horizon,
//! blind to queued-but-unlaunched work and to per-card speed) is kept as
//! [`LoadModel::BusyHorizon`] for the ablation the fleet experiments
//! report. Either way the estimates bottom out in the pipeline schedule
//! IR ([`crate::accel::pipeline::PipelineSchedule`]): `SimEngine` reads
//! its launch costs from it directly and `PjrtEngine` warms its
//! cold-start estimate from the same schedule until real launches are
//! measured.
//!
//! Since the launch-sequence IR
//! ([`crate::accel::pipeline::SequenceSchedule`]) the router is
//! warm/cold aware: a launch firing the instant its card frees ran its
//! weight stream during the previous launch (cross-launch prefetch) and
//! costs [`Engine::steady_estimate`]; a launch into an idle card pays
//! the cold [`Engine::service_estimate`]. Backlog pricing uses the warm
//! cost for queued work ([`Router::queued_price_cycles`]) — queued
//! launches run back-to-back by construction. With
//! [`crate::accel::AccelConfig::overlap_interlaunch`] off both costs
//! coincide and the pre-sequence behaviour is reproduced exactly.
//!
//! ## Energy-aware routing & idle gating
//!
//! [`LoadModel::Energy`] prices the **marginal fleet energy** of each
//! candidate card into the backlog signal: per-(card, bucket) cold/warm
//! launch energies ([`Engine::launch_energy_uj`], derived from
//! `accel::power`'s busy-fraction-weighted span power) are snapshotted
//! next to the cycle prices, converted to load cycles at
//! [`Router::with_energy_weight`] cycles per millijoule, and added to
//! the same O(log N) index keys the latency models use — at weight 0
//! the penalty is identically zero and `Energy` reproduces
//! [`LoadModel::Backlog`] **bit for bit** (the differential the
//! equivalence suite pins). [`Router::with_idle_gating`] models
//! power-gated idle cards as a cold-entry analogue: a gated card pays
//! its engine's wake-up fill ([`Engine::wakeup_cycles`]) on every cold
//! launch — charged at dispatch and priced into the cold-head
//! correction the warm/cold split already uses — and in exchange pays
//! no idle draw in [`Router::fleet_energy_uj`], the fleet-energy
//! figure the Pareto experiment reports.
//!
//! ## The allocation-free hot path
//!
//! The per-arrival **pricing and advance** path does no heap allocation
//! and no `Duration`/`f64` round-trips; the only residual allocations
//! are per *formed launch* (seat selection in
//! [`CardBatcher::take_launch`]) plus amortised container growth —
//! well under one per arrival, vs ~16 decompose `Vec`s per arrival
//! before (`rust/benches/hotpath.rs` tracks both with a counting
//! allocator):
//!
//! * **Event calendar** — virtual time advances through a
//!   [`BinaryHeap`] of per-card next-fire times instead of scanning
//!   every card per arrival (O(M·N) → O(M log N) for M arrivals over N
//!   cards). Stale entries are invalidated by a per-card epoch and
//!   skipped on pop.
//! * **Snapshotted prices** — each card's per-bucket cold/warm launch
//!   prices are converted to `u64` cycles once, at construction/reset
//!   ([`Engine::service_estimate_cycles`]); the backlog price of each
//!   queue is maintained incrementally (recomputed allocation-free from
//!   the queue length on enqueue/launch-fire), so a JSQ pick is pure
//!   integer arithmetic.
//! * **Finish-ordered completion streams** — each card appends its
//!   completions already (finish, idx)-ordered; [`Router::drain`] k-way
//!   merges the per-card streams instead of sorting the whole run.
//!
//! The pre-calendar full-scan advance and per-call `Duration` pricing
//! are retained as a differential oracle ([`Router::run_classed_scan`])
//! — the equivalence suite pins the two paths bit-identical.
//!
//! The single-request [`Router::route`] / [`Router::run_poisson`] path
//! (whole requests dispatched against the busy horizon, no batching) is
//! retained for the legacy scale-out benches.
//!
//! ## Indexed JSQ picks
//!
//! A least-loaded pick used to scan every card's load per arrival —
//! fine at N=16, the bottleneck at N=256. [`LoadIndex`] keeps three
//! lazily-invalidated heaps (idle cards by backlog price, busy cards by
//! `busy_until + backlog`, plus a release calendar that migrates a card
//! from busy to idle the first pick after its horizon passes) so a pick
//! is O(log N). The named determinism hazard — `min_by_key` returns the
//! **lowest-index** card among load ties — is preserved by ordering
//! every heap by `(key, card)` and comparing the two group candidates by
//! `(load, card)`; a debug assertion re-runs the O(N) scan on every
//! indexed pick, so the whole test suite differentially verifies the
//! index. [`Router::with_scan_pick`] forces the scan (the retained
//! oracle the sharded bench pins against).
//!
//! ## Sharded fleets (multi-threaded virtual time)
//!
//! [`ShardedRouter`] partitions the cards of a fleet into contiguous
//! per-shard [`Router`]s — each shard runs its own calendar, batchers
//! and prices in virtual time — and executes the shards on scoped
//! threads ([`std::thread::scope`]). Determinism is by construction,
//! not by locking:
//!
//! * **epoch-snapshot routing** — virtual time is cut into fixed epochs;
//!   at each (non-empty) epoch's start boundary every shard advances to
//!   the boundary and publishes a load summary, and every arrival in the
//!   epoch is assigned to a shard by a pure function of (arrival order,
//!   those summaries, a per-shard projected increment) with the same
//!   lowest-index tie-break. No assignment ever reads mid-epoch shard
//!   state, so thread interleaving cannot change it.
//! * **per-shard substreams** — generated workloads derive each shard's
//!   arrival/jitter stream from a splittable counter-based PRNG keyed by
//!   (seed, shard) ([`crate::util::prng::CounterRng`]), so the stream
//!   replays exactly regardless of thread count or chunking.
//! * **deterministic drain** — each shard's completions are already
//!   (finish, idx)-merged per card (PR 5); [`ShardedRouter`] k-way
//!   merges the shard streams one level up with the same key.
//!
//! With one shard, `ShardedRouter` degenerates **bit-for-bit** to
//! [`Router::run_classed`] — which the equivalence suite already pins to
//! the scan oracle — so the chain sharded == calendar == scan holds end
//! to end, and results are identical for every `threads` value.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;
use std::time::Duration;

use crate::accel::pipeline::CostTable;
use crate::accel::AccelConfig;
use crate::model::config::{SwinVariant, SMALL, TINY};
use crate::util::prng::{mix64, Rng};

use super::batcher::{decompose, pick_launch, BatchItem, CardBatcher, Slo, SloPolicy, Step};
use super::engine::{Engine, SimEngine, BUCKET_SIZES};
use super::fault::{CardHealth, FaultEvent, FaultPlan};
use super::workload::{ClassedArrival, ShardArrivalGen};

/// Virtual-time resolution: cycles per millisecond at the paper's
/// 200 MHz accelerator clock (the unit the fleet experiments report in).
pub const CYCLES_PER_MS: f64 = 200_000.0;

/// The router's PRNG seed (power-of-two sampling); [`Router::reset`]
/// restores it so back-to-back experiments on one router are
/// reproducible.
const ROUTER_SEED: u64 = 0xF1EE7;

fn duration_to_cycles(d: Duration) -> u64 {
    (d.as_secs_f64() * 1e3 * CYCLES_PER_MS).round() as u64
}

/// The launch sizes a card's batcher may actually use: its engine
/// buckets capped at `FleetPolicy::max_batch` (falling back to the
/// smallest — padded — bucket when the cap is below all of them), so
/// backlog pricing matches the launches the batcher will run.
fn launchable_sizes(all: &[usize], max_batch: usize) -> Vec<usize> {
    let capped: Vec<usize> = all.iter().copied().filter(|&s| s <= max_batch).collect();
    if capped.is_empty() {
        vec![*all.last().expect("engine has at least one bucket")]
    } else {
        capped
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    PowerOfTwo,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::PowerOfTwo => "power-of-two",
        }
    }
}

/// What load signal the JSQ policies compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadModel {
    /// Residual busy time only (clamped to `now`): blind to queued work
    /// that has not launched yet and to per-card service speed. The
    /// pre-batcher baseline.
    BusyHorizon,
    /// Residual busy time **plus** the card's queue priced through
    /// `decompose` + `service_estimate` — what the card will actually
    /// spend clearing its backlog.
    Backlog,
    /// [`LoadModel::Backlog`] plus the **marginal fleet energy** of
    /// routing one more request to the card, converted to load cycles at
    /// [`Router::with_energy_weight`] cycles per millijoule: an idle,
    /// empty card is charged a cold smallest-bucket launch
    /// ([`Engine::launch_energy_uj`]), a card with work ahead the warm
    /// largest-bucket launch amortised per image
    /// ([`Engine::steady_energy_uj`]). At weight 0 the penalty vanishes
    /// and `Energy` **is** `Backlog`, bit for bit — the differential
    /// oracle the equivalence suite pins.
    Energy,
}

impl LoadModel {
    pub fn name(self) -> &'static str {
        match self {
            LoadModel::BusyHorizon => "busy-horizon",
            LoadModel::Backlog => "backlog",
            LoadModel::Energy => "energy",
        }
    }
}

/// Batching knobs of the per-card queues (virtual-time counterpart of
/// [`super::BatchPolicy`]).
#[derive(Debug, Clone, Copy)]
pub struct FleetPolicy {
    pub max_batch: usize,
    /// Per-card admission bound: a request routed to a card whose queue
    /// is full is **shed** (counted by [`Router::shed_count`]), and a
    /// queue at the bound launches immediately instead of waiting out a
    /// deadline — the virtual-time counterpart of the wall-clock
    /// server's bounded channel.
    pub queue_cap: usize,
    /// Per-class flush deadlines.
    pub slo: SloPolicy,
}

impl Default for FleetPolicy {
    fn default() -> Self {
        FleetPolicy {
            max_batch: 8,
            queue_cap: 256,
            slo: SloPolicy::default(),
        }
    }
}

impl FleetPolicy {
    fn wait_cycles(&self) -> [u64; 2] {
        [
            duration_to_cycles(self.slo.interactive_max_wait),
            duration_to_cycles(self.slo.batch_max_wait),
        ]
    }
}

/// Snapshot of one card's per-bucket launch prices in virtual cycles,
/// index-aligned with the engine's full bucket ladder (descending). The
/// conversion from the engine's `Duration` estimates happens exactly
/// once ([`Engine::service_estimate_cycles`], bit-identical to the old
/// per-call round-trip), so the per-arrival loop is pure `u64` work.
#[derive(Debug, Clone)]
struct CardPrices {
    /// The engine's bucket ladder, descending — shared with the card's
    /// batcher (one allocation per distinct ladder in the fleet).
    sizes: Arc<[usize]>,
    /// Cold launch price per ladder entry.
    cold: Vec<u64>,
    /// Warm (steady-state) launch price per ladder entry.
    warm: Vec<u64>,
    /// Cold launch energy per ladder entry, integer µJ
    /// ([`Engine::launch_energy_uj`]; 0 for backends with no model).
    cold_e: Vec<u64>,
    /// Warm launch energy per ladder entry, integer µJ.
    warm_e: Vec<u64>,
    /// Wake-up fill a power-gated card pays on a cold launch, cycles.
    wakeup: u64,
    /// Idle (ungated) draw, µW — what gating reclaims between launches.
    idle_uw: u64,
}

impl CardPrices {
    fn snapshot(e: &dyn Engine, sizes: Arc<[usize]>) -> Self {
        let cold = sizes
            .iter()
            .map(|&b| e.service_estimate_cycles(b, CYCLES_PER_MS).max(1))
            .collect();
        let warm = sizes
            .iter()
            .map(|&b| e.steady_estimate_cycles(b, CYCLES_PER_MS).max(1))
            .collect();
        let cold_e = sizes.iter().map(|&b| e.launch_energy_uj(b)).collect();
        let warm_e = sizes.iter().map(|&b| e.steady_energy_uj(b)).collect();
        CardPrices {
            sizes,
            cold,
            warm,
            cold_e,
            warm_e,
            wakeup: e.wakeup_cycles(),
            idle_uw: e.idle_power_uw(),
        }
    }

    fn lookup(&self, batch: usize, warm: bool) -> Option<u64> {
        let i = self.sizes.iter().position(|&s| s == batch)?;
        Some(if warm { self.warm[i] } else { self.cold[i] })
    }

    fn lookup_energy(&self, batch: usize, warm: bool) -> Option<u64> {
        let i = self.sizes.iter().position(|&s| s == batch)?;
        Some(if warm { self.warm_e[i] } else { self.cold_e[i] })
    }
}

/// O(log N) least-loaded pick structure (see the module docs).
///
/// Every card always has exactly one **live** representation, stamped
/// with its current version: a `busy` entry keyed by what
/// [`Router::load_cycles`] reads while `now < busy_until` (the key minus
/// `now` is the load), paired with a `release` entry at `busy_until`
/// that, once due at a pick, publishes the card's `idle` entry (the load
/// while the card sits idle — a pure key, independent of `now`). State
/// changes bump the version; stale entries are discarded when they
/// surface at a heap top, and a heap that outgrows the live set is
/// compacted. Pick times within a run are nondecreasing (arrival streams
/// are ascending), which the release migration relies on — the per-pick
/// debug assertion against the O(N) scan enforces the equivalence.
#[derive(Debug)]
struct LoadIndex {
    n: usize,
    ver: Vec<u64>,
    /// Load while idle (`now >= busy_until`): backlog price + cold-head
    /// correction under [`LoadModel::Backlog`], 0 under `BusyHorizon`.
    idle_key: Vec<u64>,
    /// Load while busy is `busy_key - now`: `busy_until + backlog` under
    /// `Backlog`, `busy_until` under `BusyHorizon`.
    busy_key: Vec<u64>,
    /// `busy_until` at the last touch — when the busy→idle migration is
    /// due, and the busy entry's validity horizon.
    release_at: Vec<u64>,
    idle: BinaryHeap<Reverse<(u64, usize, u64)>>,
    busy: BinaryHeap<Reverse<(u64, usize, u64)>>,
    release: BinaryHeap<Reverse<(u64, usize, u64)>>,
}

impl LoadIndex {
    fn new(n: usize) -> Self {
        LoadIndex {
            n,
            ver: vec![0; n],
            idle_key: vec![0; n],
            busy_key: vec![0; n],
            release_at: vec![0; n],
            idle: BinaryHeap::new(),
            busy: BinaryHeap::new(),
            release: BinaryHeap::new(),
        }
    }

    /// Card `i`'s load state changed: stamp a new version and publish
    /// fresh busy + release entries (the idle entry is published by the
    /// release calendar at the first pick past `busy_until`).
    fn touch(&mut self, i: usize, idle_key: u64, busy_key: u64, busy_until: u64) {
        self.ver[i] += 1;
        let v = self.ver[i];
        self.idle_key[i] = idle_key;
        self.busy_key[i] = busy_key;
        self.release_at[i] = busy_until;
        self.busy.push(Reverse((busy_key, i, v)));
        self.release.push(Reverse((busy_until, i, v)));
        self.maybe_compact();
    }

    fn clear(&mut self) {
        self.idle.clear();
        self.busy.clear();
        self.release.clear();
        // versions keep counting: cleared entries can never resurface
    }

    /// Lowest-`(load, card)` pick at `now` — reproduces the scan's
    /// first-minimum (lowest-index tie-break) exactly.
    fn pick(&mut self, now: u64) -> usize {
        // publish idle entries for cards whose horizon has passed
        while let Some(&Reverse((at, i, v))) = self.release.peek() {
            if at > now {
                break;
            }
            self.release.pop();
            if v == self.ver[i] {
                self.idle.push(Reverse((self.idle_key[i], i, v)));
            }
        }
        // best idle candidate: load == key
        let cand_idle = loop {
            match self.idle.peek() {
                None => break None,
                Some(&Reverse((key, i, v))) => {
                    if v == self.ver[i] {
                        break Some((key, i));
                    }
                    self.idle.pop();
                }
            }
        };
        // best busy candidate: load == key - now while still busy
        let cand_busy = loop {
            match self.busy.peek() {
                None => break None,
                Some(&Reverse((key, i, v))) => {
                    if v != self.ver[i] || self.release_at[i] <= now {
                        // stale, or migrated to idle by the release pass
                        self.busy.pop();
                        continue;
                    }
                    break Some((key - now, i));
                }
            }
        };
        match (cand_idle, cand_busy) {
            (Some(a), Some(b)) => (if a <= b { a } else { b }).1,
            (Some(a), None) => a.1,
            (None, Some(b)) => b.1,
            (None, None) => unreachable!("every card has a live index entry"),
        }
    }

    /// Lazy invalidation keeps stale entries buried mid-heap; rebuild a
    /// heap that outgrows the live set so memory stays O(N) over
    /// billion-arrival runs (amortised O(1) per touch).
    fn maybe_compact(&mut self) {
        let cap = 4 * self.n + 64;
        let ver = &self.ver;
        let live = |h: &mut BinaryHeap<Reverse<(u64, usize, u64)>>| {
            let kept: Vec<_> =
                h.drain().filter(|&Reverse((_, i, v))| v == ver[i]).collect();
            *h = BinaryHeap::from(kept);
        };
        if self.busy.len() > cap {
            live(&mut self.busy);
        }
        if self.release.len() > cap {
            live(&mut self.release);
        }
        if self.idle.len() > cap {
            live(&mut self.idle);
        }
    }
}

/// A [`FaultEvent`] normalised for the router's timelines: `Degrade`
/// expands into a start and an end op, so every op is instantaneous and
/// the whole plan flattens into one `(at, card)`-ordered queue.
#[derive(Debug, Clone, Copy)]
enum FaultOp {
    Crash,
    DegradeStart(u64),
    DegradeEnd,
    Join,
    Leave,
}

/// Flatten a plan into the global `(at, card)`-ordered op queue both
/// router paths (calendar and scan oracle) consume. Stable sort: ties at
/// one `(at, card)` keep per-card schedule order.
fn flatten_plan(plan: &FaultPlan) -> Vec<(u64, usize, FaultOp)> {
    let mut q: Vec<(u64, usize, FaultOp)> = Vec::new();
    for (card, events) in plan.events.iter().enumerate() {
        for ev in events {
            match *ev {
                FaultEvent::Crash { at } => q.push((at, card, FaultOp::Crash)),
                FaultEvent::Join { at } => q.push((at, card, FaultOp::Join)),
                FaultEvent::Leave { at } => q.push((at, card, FaultOp::Leave)),
                FaultEvent::Degrade { at, factor_pct, until } => {
                    q.push((at, card, FaultOp::DegradeStart(factor_pct)));
                    q.push((until.max(at), card, FaultOp::DegradeEnd));
                }
            }
        }
    }
    q.sort_by_key(|&(at, card, _)| (at, card));
    q
}

/// Fault-layer counters of one router (all zero when no plan is set).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Budgeted re-launch attempts after crash loss.
    pub retries: u64,
    /// Requests re-entered through the normal assignment path (crash
    /// survivors plus drained queues of leaving/crashed cards).
    pub redispatched: u64,
    /// In-flight results retracted by fail-stop crashes.
    pub crash_lost: u64,
    /// Requests lost for good: retry budget exhausted, or no live card
    /// with queue room to redispatch to.
    pub lost: u64,
}

/// The fleet router.
pub struct Router {
    pub engines: Vec<Box<dyn Engine>>,
    pub policy: Policy,
    /// Load signal for the JSQ policies (see [`LoadModel`]).
    pub load: LoadModel,
    fleet: FleetPolicy,
    /// Per-card continuous-batcher queues (payload: request index).
    cards: Vec<CardBatcher<usize>>,
    /// Per-card launch sizes (engine buckets capped at `max_batch`),
    /// precomputed — backlog pricing runs per arrival on the hot path.
    launchable: Vec<Vec<usize>>,
    /// Per-card bucket-price snapshot (see [`CardPrices`]).
    prices: Vec<CardPrices>,
    /// Cached backlog price of each card's current queue, maintained on
    /// enqueue/launch-fire — a JSQ pick never re-decomposes a queue.
    queue_price: Vec<u64>,
    /// Virtual cycle each engine next goes idle.
    busy_until: Vec<u64>,
    /// Completed requests per engine.
    served: Vec<u64>,
    /// Per-card completion streams, (finish, idx)-ordered by
    /// construction; [`Router::drain`] k-way merges them.
    completions: Vec<Vec<FleetCompletion>>,
    /// Event calendar: `Reverse((next fire, card, epoch))`. Entries are
    /// lazily invalidated — only the card's current epoch is live.
    calendar: BinaryHeap<Reverse<(u64, usize, u64)>>,
    /// Per-card epoch of the live calendar entry.
    epoch: Vec<u64>,
    submitted: usize,
    /// Requests dropped because the picked card's queue was full.
    shed: u64,
    /// [`LoadModel::Energy`]'s exchange rate, cycles of load per mJ of
    /// marginal launch energy. 0 = energy priced at nothing (the
    /// default): `Energy` coincides with `Backlog` bit for bit.
    energy_weight: u64,
    /// Power-gate idle cards: every cold launch pays its engine's
    /// wake-up fill ([`Engine::wakeup_cycles`]) — charged at dispatch
    /// and priced into the load signal's cold-head correction — and in
    /// exchange gated cards pay no idle draw
    /// ([`Router::fleet_energy_uj`]).
    gate_idle: bool,
    /// Total launch energy dispatched so far, µJ (snapshot prices).
    energy_spent_uj: u64,
    /// Per-card busy cycles dispatched so far — the complement of each
    /// card's idle time when billing idle draw over a horizon.
    busy_cycles: Vec<u64>,
    next_rr: usize,
    rng: Rng,
    /// O(log N) least-loaded pick index (see [`LoadIndex`]).
    index: LoadIndex,
    /// Force the O(N) scan for least-loaded picks — the retained oracle
    /// the sharded bench pins the indexed path against.
    force_scan_pick: bool,
    /// Fault plan, if any. `None` means every fault branch below is
    /// dead and the router behaves exactly as before the fault layer
    /// (an **empty** plan reproduces the same results bit for bit — the
    /// zero-fault identity the equivalence suite pins).
    plan: Option<FaultPlan>,
    /// The plan flattened into one `(at, card)`-ordered op queue
    /// (static; `fault_cursor` walks it).
    fault_queue: Vec<(u64, usize, FaultOp)>,
    /// Next unprocessed op in `fault_queue`.
    fault_cursor: usize,
    /// Per-card health; all `Up` when no plan is set.
    health: Vec<CardHealth>,
    /// Per-card active launch-cost multiplier, percent (100 = none).
    degrade_pct: Vec<u64>,
    /// Crash-retry ledger: redispatch attempts per request tag.
    retry_count: HashMap<usize, u32>,
    /// Net capacity lost: +1 per crash/leave of a live card, −1 per
    /// join. Degraded-mode admission control is active while positive.
    net_down: i64,
    /// Fault counters (see [`FaultCounters`]).
    faults: FaultCounters,
}

/// Result of a routed request (legacy immediate-dispatch path).
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub device: usize,
    pub latency_cycles: u64,
    pub queued_cycles: u64,
}

/// One completed request of a queued fleet experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetCompletion {
    /// Submission index (position in the arrival stream).
    pub idx: usize,
    pub device: usize,
    pub class: Slo,
    /// Arrival cycle.
    pub arrival: u64,
    /// Cycle its launch started.
    pub start: u64,
    /// Cycle its launch completed.
    pub finish: u64,
}

impl FleetCompletion {
    pub fn latency_cycles(&self) -> u64 {
        self.finish - self.arrival
    }

    /// Queueing + batching wait before the launch started.
    pub fn wait_cycles(&self) -> u64 {
        self.start - self.arrival
    }

    pub fn latency_ms(&self) -> f64 {
        self.latency_cycles() as f64 / CYCLES_PER_MS
    }
}

/// Latencies (ms) of all completions.
pub fn completion_latencies_ms(comps: &[FleetCompletion]) -> Vec<f64> {
    comps.iter().map(FleetCompletion::latency_ms).collect()
}

/// Latencies (ms) of one class's completions.
pub fn class_latencies_ms(comps: &[FleetCompletion], class: Slo) -> Vec<f64> {
    comps
        .iter()
        .filter(|c| c.class == class)
        .map(FleetCompletion::latency_ms)
        .collect()
}

/// Summary percentiles of a fleet experiment — `[p50, p99,
/// interactive p99, batch p99]` in ms (an absent class reports 0) — so
/// the acceptance test, benches, example and CLI all tabulate the same
/// statistics.
pub fn fleet_percentiles(comps: &[FleetCompletion]) -> [f64; 4] {
    let all = completion_latencies_ms(comps);
    [
        percentile(&all, 0.50),
        percentile(&all, 0.99),
        percentile(&class_latencies_ms(comps, Slo::Interactive), 0.99),
        percentile(&class_latencies_ms(comps, Slo::Batch), 0.99),
    ]
}

impl Router {
    /// A homogeneous simulated fleet (the classic fleet experiment):
    /// **one** shared [`CostTable`] — the workload graph is lowered and
    /// the warm costs converged once, then every card reads the same
    /// `Arc` (N× cheaper construction than N independent engines).
    pub fn new(
        cards: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        policy: Policy,
    ) -> Self {
        let table = Arc::new(CostTable::for_variant(variant, cfg, &BUCKET_SIZES));
        Router::from_engines(
            (0..cards)
                .map(|i| {
                    Box::new(SimEngine::with_table(i, variant, Arc::clone(&table), 0.0))
                        as Box<dyn Engine>
                })
                .collect(),
            policy,
        )
    }

    /// Route over any engines — simulated cards, PJRT backends, or a mix.
    pub fn from_engines(engines: Vec<Box<dyn Engine>>, policy: Policy) -> Self {
        Router::with_fleet(engines, policy, FleetPolicy::default())
    }

    /// Full constructor: engines, policy, and per-card batching knobs.
    pub fn with_fleet(
        engines: Vec<Box<dyn Engine>>,
        policy: Policy,
        fleet: FleetPolicy,
    ) -> Self {
        assert!(!engines.is_empty(), "router needs at least one engine");
        let n = engines.len();
        let wait = fleet.wait_cycles();
        // one shared ladder allocation per *distinct* bucket ladder in
        // the fleet (a homogeneous fleet shares a single Arc across its
        // batchers and price snapshots)
        let mut ladders: Vec<Arc<[usize]>> = Vec::new();
        let sizes: Vec<Arc<[usize]>> = engines
            .iter()
            .map(|e| match ladders.iter().find(|l| l.as_ref() == e.batch_sizes()) {
                Some(l) => Arc::clone(l),
                None => {
                    let l: Arc<[usize]> = Arc::from(e.batch_sizes());
                    ladders.push(Arc::clone(&l));
                    l
                }
            })
            .collect();
        let cards = sizes
            .iter()
            .map(|l| CardBatcher::new(Arc::clone(l), fleet.max_batch, fleet.queue_cap, wait))
            .collect();
        let launchable = engines
            .iter()
            .map(|e| launchable_sizes(e.batch_sizes(), fleet.max_batch))
            .collect();
        let prices = engines
            .iter()
            .zip(&sizes)
            .map(|(e, l)| CardPrices::snapshot(e.as_ref(), Arc::clone(l)))
            .collect();
        let mut r = Router {
            engines,
            policy,
            load: LoadModel::Backlog,
            fleet,
            cards,
            launchable,
            prices,
            queue_price: vec![0; n],
            busy_until: vec![0; n],
            served: vec![0; n],
            completions: vec![Vec::new(); n],
            calendar: BinaryHeap::new(),
            epoch: vec![0; n],
            submitted: 0,
            shed: 0,
            energy_weight: 0,
            gate_idle: false,
            energy_spent_uj: 0,
            busy_cycles: vec![0; n],
            next_rr: 0,
            rng: Rng::new(ROUTER_SEED),
            index: LoadIndex::new(n),
            force_scan_pick: false,
            plan: None,
            fault_queue: Vec::new(),
            fault_cursor: 0,
            health: vec![CardHealth::Up; n],
            degrade_pct: vec![100; n],
            retry_count: HashMap::new(),
            net_down: 0,
            faults: FaultCounters::default(),
        };
        r.index_rebuild();
        r
    }

    /// Builder: switch the JSQ load signal (ablations).
    pub fn with_load(mut self, load: LoadModel) -> Self {
        self.set_load(load);
        self
    }

    /// Switch the JSQ load signal in place (the index keys depend on
    /// it, so it is rebuilt). Prefer this over writing the pub `load`
    /// field directly — a direct write leaves the pick index keyed by
    /// the old model (the per-pick debug assertion catches it).
    #[doc(hidden)]
    pub fn set_load(&mut self, load: LoadModel) {
        self.load = load;
        self.index_rebuild();
    }

    /// Builder: force O(N)-scan least-loaded picks (the pre-index oracle
    /// the sharded fleet bench pins the indexed path against).
    #[doc(hidden)]
    pub fn with_scan_pick(mut self) -> Self {
        self.force_scan_pick = true;
        self
    }

    /// Builder: [`LoadModel::Energy`]'s exchange rate in load cycles per
    /// millijoule of marginal launch energy. At 0 (the default) the
    /// energy penalty vanishes and `Energy` routes exactly like
    /// [`LoadModel::Backlog`]. As a yardstick: a cold TINY batch-1
    /// launch is ≈230 mJ (≈23 ms at ≈10 W), so a weight of
    /// 1 000 cycles/mJ prices it at ≈230 k cycles ≈ 1.2 ms of load —
    /// weights in the low thousands trade milliseconds against joules.
    pub fn with_energy_weight(mut self, cycles_per_mj: u64) -> Self {
        self.set_energy_weight(cycles_per_mj);
        self
    }

    /// Switch the energy weight in place (the [`LoadModel::Energy`] index
    /// keys depend on it, so the pick index is rebuilt).
    #[doc(hidden)]
    pub fn set_energy_weight(&mut self, cycles_per_mj: u64) {
        self.energy_weight = cycles_per_mj;
        self.index_rebuild();
    }

    /// Builder: power-gate idle cards. Gating drops a card's resident
    /// weight window, so every **cold** launch (one that finds its card
    /// idle — exactly the launches the sequence IR already prices cold)
    /// additionally pays the engine's wake-up fill
    /// ([`Engine::wakeup_cycles`]), charged at dispatch and mirrored in
    /// the load signal's cold-head correction; in exchange gated cards
    /// pay no idle draw in [`Router::fleet_energy_uj`]. Off by default —
    /// the gating-off, zero-weight configuration reproduces the
    /// latency-only router bit for bit.
    pub fn with_idle_gating(mut self, gate: bool) -> Self {
        self.set_idle_gating(gate);
        self
    }

    /// Switch idle gating in place (rebuilds the pick index — the
    /// cold-head correction in the index keys includes the wake fill).
    #[doc(hidden)]
    pub fn set_idle_gating(&mut self, gate: bool) {
        self.gate_idle = gate;
        self.index_rebuild();
    }

    /// Builder: install a deterministic [`FaultPlan`] on the queued
    /// fleet path (the legacy immediate-dispatch path ignores it). An
    /// **empty** plan reproduces the plan-free router bit for bit.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.set_fault_plan(plan);
        self
    }

    /// Install a fault plan in place (see [`Self::with_faults`]).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        assert_eq!(
            plan.cards(),
            self.engines.len(),
            "fault plan must cover every card"
        );
        self.fault_queue = flatten_plan(&plan);
        self.plan = Some(plan);
        self.fault_runtime_reset();
        self.index_rebuild();
    }

    /// Rewind the fault runtime to the start of the plan: cursor, health
    /// (initially-down for join-first cards), degrade factors, the retry
    /// ledger, the capacity counter and every fault counter.
    fn fault_runtime_reset(&mut self) {
        self.fault_cursor = 0;
        self.degrade_pct.fill(100);
        self.retry_count.clear();
        self.net_down = 0;
        self.faults = FaultCounters::default();
        match &self.plan {
            Some(p) => {
                for i in 0..self.health.len() {
                    self.health[i] = p.initial_health(i);
                }
            }
            None => self.health.fill(CardHealth::Up),
        }
    }

    /// Health of card `i` (always `Up` without a plan).
    pub fn health(&self, i: usize) -> CardHealth {
        self.health[i]
    }

    /// Cards per health state, indexed `[up, degraded, draining, down]`.
    pub fn health_counts(&self) -> [u64; 4] {
        let mut counts = [0u64; 4];
        for &h in &self.health {
            let k = match h {
                CardHealth::Up => 0,
                CardHealth::Degraded => 1,
                CardHealth::Draining => 2,
                CardHealth::Down => 3,
            };
            counts[k] += 1;
        }
        counts
    }

    /// Fault counters since the last reset.
    pub fn fault_counters(&self) -> FaultCounters {
        self.faults
    }

    /// Whether card `i` must be excluded from picks (fault plans only).
    #[inline]
    fn unpickable(&self, i: usize) -> bool {
        self.plan.is_some() && !self.health[i].pickable()
    }

    /// Degrade multiplier on card `i`'s launch cycles (100 = none; the
    /// energy model and wake fill are deliberately not scaled — a slow
    /// card burns the same joules per launch and wakes at full speed).
    #[inline]
    fn scale_degraded(&self, i: usize, cycles: u64) -> u64 {
        let pct = self.degrade_pct[i];
        if pct == 100 {
            cycles
        } else {
            cycles.saturating_mul(pct) / 100
        }
    }

    /// Virtual cycle at which engine `i` next goes idle.
    pub fn busy_until(&self, i: usize) -> u64 {
        self.busy_until[i]
    }

    /// Requests queued (not yet launched) on card `i`.
    pub fn queue_depth(&self, i: usize) -> usize {
        self.cards[i].len()
    }

    /// Enqueue directly onto card `i` without routing or advancing —
    /// test seeding only; keeps the price cache and calendar coherent.
    #[doc(hidden)]
    pub fn seed_queue(&mut self, i: usize, payload: usize, class: Slo, at: u64) {
        self.cards[i].push(payload, class, at);
        self.submitted = self.submitted.max(payload + 1);
        self.reprice(i);
        self.arm(i);
    }

    /// Cold price of one batch-`batch` launch on card `i`, in cycles:
    /// snapshot lookup for ladder buckets, engine fast path otherwise
    /// (only the legacy arbitrary-batch `route_batch` misses).
    fn service_cycles(&self, i: usize, batch: usize) -> u64 {
        let base = self.prices[i].lookup(batch, false).unwrap_or_else(|| {
            self.engines[i]
                .service_estimate_cycles(batch, CYCLES_PER_MS)
                .max(1)
        });
        self.scale_degraded(i, base)
    }

    /// Warm (steady-state) cost of one more batch-`batch` launch on card
    /// `i` — what a launch actually costs when it starts the moment the
    /// card frees (cross-launch weight prefetch hid its cold entry).
    fn steady_cycles(&self, i: usize, batch: usize) -> u64 {
        let base = self.prices[i].lookup(batch, true).unwrap_or_else(|| {
            self.engines[i]
                .steady_estimate_cycles(batch, CYCLES_PER_MS)
                .max(1)
        });
        self.scale_degraded(i, base)
    }

    /// Price `queued` requests on card `i`: the greedy launch plan the
    /// batcher will run, each launch at its **warm** steady-state cost —
    /// queued work runs back-to-back behind whatever is ahead of it,
    /// which is exactly the regime cross-launch prefetch models. With
    /// `overlap_interlaunch` off the warm and cold estimates coincide
    /// and backlog pricing degenerates to the cold-only form.
    /// ([`Self::load_cycles`] adds the cold-head correction for idle
    /// cards, whose *first* launch cannot have been prefetched.)
    ///
    /// Allocation-free: the greedy largest-fit decomposition is walked
    /// directly over the launchable ladder (division instead of the
    /// repeated-subtraction `Vec` the old path materialised per pick).
    pub fn queued_price_cycles(&self, i: usize, queued: usize) -> u64 {
        let mut rem = queued;
        let mut sum = 0u64;
        for &s in &self.launchable[i] {
            if rem >= s {
                sum += (rem / s) as u64 * self.steady_cycles(i, s);
                rem %= s;
            }
        }
        if rem > 0 {
            // smaller than the smallest launchable size: one padded launch
            let &pad = self.launchable[i].last().expect("non-empty ladder");
            sum += self.steady_cycles(i, pad);
        }
        sum
    }

    /// Marginal fleet energy of routing one more request to card `i`,
    /// in integer µJ: an idle card with an empty queue pays a cold
    /// smallest-bucket launch (the request will wake the card alone); a
    /// card with work ahead amortises the request into a warm
    /// largest-launchable-bucket launch. Snapshot lookups only — this
    /// sits on the per-arrival pick path.
    fn marginal_energy_uj(&self, i: usize, idle: bool) -> u64 {
        if idle && self.cards[i].len() == 0 {
            let &pad = self.launchable[i].last().expect("non-empty ladder");
            self.prices[i]
                .lookup_energy(pad, false)
                .unwrap_or_else(|| self.engines[i].launch_energy_uj(pad))
        } else {
            let &big = self.launchable[i].first().expect("non-empty ladder");
            self.prices[i]
                .lookup_energy(big, true)
                .unwrap_or_else(|| self.engines[i].steady_energy_uj(big))
                / big.max(1) as u64
        }
    }

    /// [`Self::marginal_energy_uj`] converted to load cycles at the
    /// configured weight (cycles per mJ; integer: µJ × weight / 1000).
    /// 0 at weight 0 — the exact-degeneracy guarantee.
    fn energy_penalty(&self, i: usize, idle: bool) -> u64 {
        if self.energy_weight == 0 {
            return 0;
        }
        self.marginal_energy_uj(i, idle)
            .saturating_mul(self.energy_weight)
            / 1000
    }

    /// Wake-up fill a cold launch on card `i` pays under idle gating
    /// (0 with gating off — every pre-gating price is reproduced).
    fn wake_cycles(&self, i: usize) -> u64 {
        if self.gate_idle {
            self.prices[i].wakeup
        } else {
            0
        }
    }

    /// Refresh card `i`'s cached backlog price (call whenever its queue
    /// length changes — enqueue or launch-fire). Also republishes the
    /// card's pick-index entries: every load-state change routes through
    /// here (or through [`Self::index_touch`] on the legacy busy-only
    /// path), which is what keeps the index coherent.
    fn reprice(&mut self, i: usize) {
        self.queue_price[i] = self.queued_price_cycles(i, self.cards[i].len());
        self.index_touch(i);
    }

    /// Republish card `i`'s entries in the least-loaded pick index from
    /// its current (busy horizon, backlog) state. An unpickable card is
    /// parked as a never-releasing busy entry at `u64::MAX` — it can
    /// only win a pick when every card is unpickable, and then the
    /// `(key, card)` heap order reproduces the scan's lowest-index
    /// tie-break exactly (all keys equal).
    fn index_touch(&mut self, i: usize) {
        if self.unpickable(i) {
            self.index.touch(i, u64::MAX, u64::MAX, u64::MAX);
            return;
        }
        let (idle_key, busy_key) = self.index_keys(i);
        self.index.touch(i, idle_key, busy_key, self.busy_until[i]);
    }

    /// The card's index keys under the active load model — by
    /// construction `idle_key == load_cycles(i, now)` whenever
    /// `now >= busy_until[i]`, and `busy_key - now == load_cycles(i,
    /// now)` whenever `now < busy_until[i]`.
    fn index_keys(&self, i: usize) -> (u64, u64) {
        match self.load {
            LoadModel::BusyHorizon => (0, self.busy_until[i]),
            LoadModel::Backlog | LoadModel::Energy => {
                let n = self.cards[i].len();
                let mut idle = self.queue_price[i];
                if n > 0 {
                    // the idle-card cold-head correction of load_cycles
                    let head = pick_launch(n, &self.launchable[i]);
                    idle += self
                        .service_cycles(i, head)
                        .saturating_sub(self.steady_cycles(i, head))
                        + self.wake_cycles(i);
                }
                let mut busy = self.busy_until[i] + self.queue_price[i];
                if self.load == LoadModel::Energy {
                    idle = idle.saturating_add(self.energy_penalty(i, true));
                    busy = busy.saturating_add(self.energy_penalty(i, false));
                }
                (idle, busy)
            }
        }
    }

    /// Rebuild the pick index from scratch (reset, load-model switch).
    fn index_rebuild(&mut self) {
        self.index.clear();
        for i in 0..self.engines.len() {
            self.index_touch(i);
        }
    }

    /// The load signal for card `i` at `now`, in cycles of work ahead.
    /// An unpickable card (down, draining, not yet joined) reports
    /// `u64::MAX`: the survivor fleet's capacity is what the JSQ
    /// policies compare, never a dead card's stale horizon.
    pub fn load_cycles(&self, i: usize, now: u64) -> u64 {
        if self.unpickable(i) {
            return u64::MAX;
        }
        let residual = self.busy_until[i].saturating_sub(now);
        match self.load {
            LoadModel::BusyHorizon => residual,
            LoadModel::Backlog | LoadModel::Energy => {
                let n = self.cards[i].len();
                debug_assert_eq!(
                    self.queue_price[i],
                    self.queued_price_cycles(i, n),
                    "stale backlog cache on card {i}"
                );
                let mut price = residual + self.queue_price[i];
                if residual == 0 && n > 0 {
                    // the head launch finds an idle card: dispatch will
                    // charge it the cold cost (`advance_card`), so the
                    // signal must too — otherwise idle cards look
                    // (cold − warm) cheaper than busy ones per launch.
                    // Under idle gating a cold launch also wakes the
                    // card, so the wake fill rides the same correction.
                    let head = pick_launch(n, &self.launchable[i]);
                    price += self
                        .service_cycles(i, head)
                        .saturating_sub(self.steady_cycles(i, head))
                        + self.wake_cycles(i);
                }
                if self.load == LoadModel::Energy {
                    price = price.saturating_add(self.energy_penalty(i, residual == 0));
                }
                price
            }
        }
    }

    fn pick(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => self.pick_round_robin(),
            Policy::LeastLoaded => {
                if self.force_scan_pick {
                    return (0..self.engines.len())
                        .min_by_key(|&i| self.load_cycles(i, now))
                        .unwrap();
                }
                let i = self.index.pick(now);
                debug_assert_eq!(
                    i,
                    (0..self.engines.len())
                        .min_by_key(|&j| self.load_cycles(j, now))
                        .unwrap(),
                    "pick index diverged from the O(N) scan at now={now}"
                );
                i
            }
            Policy::PowerOfTwo => {
                let n = self.engines.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                // loads are clamped to `now` (regression: comparing raw
                // `busy_until` let a stale horizon from an old burst bias
                // the choice between two currently idle cards)
                if self.load_cycles(a, now) <= self.load_cycles(b, now) {
                    a
                } else {
                    b
                }
            }
        }
    }

    /// Round-robin pick. With a fault plan active, unpickable cards are
    /// skipped (the cursor still advances past them, so a card coming
    /// back up rejoins the rotation in place); with every card down the
    /// plain cursor pick stands and the submit path sheds. Without a
    /// plan this is exactly the original one-step rotation.
    fn pick_round_robin(&mut self) -> usize {
        let n = self.engines.len();
        let mut i = self.next_rr;
        self.next_rr = (self.next_rr + 1) % n;
        if self.plan.is_some() {
            let mut hops = 0;
            while !self.health[i].pickable() && hops < n {
                i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % n;
                hops += 1;
            }
        }
        i
    }

    // --- queued fleet path (per-card continuous batchers) ---------------

    /// Submit one request at virtual cycle `arrival`: pick a card by the
    /// configured load signal and join its batcher queue (launches fire
    /// event-driven as virtual time advances). Returns the card index,
    /// or `None` when the picked card's queue is at `queue_cap` and the
    /// request is shed — the per-card queues are genuinely bounded.
    pub fn submit_classed(&mut self, arrival: u64, class: Slo) -> Option<usize> {
        let tag = self.submitted;
        self.submit_classed_tagged(arrival, class, tag)
    }

    /// [`Self::submit_classed`] with a caller-chosen completion tag
    /// (`FleetCompletion::idx`) instead of the admit-order counter. The
    /// sharded router tags with global stream positions and renumbers to
    /// admit order at drain — the tag value never influences routing,
    /// batching or pricing, only the completion record (and, for
    /// monotone tags, (finish, idx) order-compatibly).
    #[doc(hidden)]
    pub fn submit_classed_tagged(
        &mut self,
        arrival: u64,
        class: Slo,
        tag: usize,
    ) -> Option<usize> {
        self.advance_to(arrival);
        let i = self.pick(arrival);
        if !self.admit(i, class) {
            self.shed += 1;
            return None;
        }
        self.submitted += 1;
        self.cards[i].push(tag, class, arrival);
        self.advance_card(i, arrival);
        self.arm(i);
        Some(i)
    }

    /// Admission check for a request of `class` picked onto card `i`:
    /// the queue bound, plus — with a fault plan active — the health
    /// gate (an unpickable card can still be picked when the whole
    /// fleet is down) and degraded-mode admission control: while the
    /// fleet is short of capacity (`net_down > 0`, i.e. more cards have
    /// crashed/left than joined), Batch-class requests are shed once the
    /// picked card's queue is half full, reserving the remaining
    /// headroom for Interactive traffic. Without a plan this is exactly
    /// the original queue-bound check.
    fn admit(&self, i: usize, class: Slo) -> bool {
        if self.cards[i].len() >= self.fleet.queue_cap {
            return false;
        }
        if self.plan.is_some() {
            if !self.health[i].pickable() {
                return false;
            }
            if self.net_down > 0
                && class == Slo::Batch
                && self.cards[i].len() >= self.fleet.queue_cap / 2
            {
                return false;
            }
        }
        true
    }

    /// Re-arm card `i`'s calendar entry from its current queue/busy
    /// state; any older entry for the card is invalidated by the epoch
    /// bump and skipped when popped.
    fn arm(&mut self, i: usize) {
        self.epoch[i] += 1;
        if let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) {
            self.calendar.push(Reverse((fire, i, self.epoch[i])));
        }
    }

    /// Advance virtual time to `now`, firing due launches — via the
    /// event calendar: only cards whose next fire time is due are
    /// touched (the pre-calendar path scanned the whole fleet per
    /// arrival; [`Self::run_classed_scan`] keeps that as the oracle).
    /// With a fault plan active, scheduled fault ops are interleaved at
    /// their exact `(at, card)` calendar positions.
    pub fn advance_to(&mut self, now: u64) {
        if self.fault_cursor >= self.fault_queue.len() {
            // fast path (and the whole path when no plan is set): no
            // pending fault ops, plain calendar pops
            self.advance_calendar(now, usize::MAX);
            return;
        }
        while let Some(&(at, card, op)) = self.fault_queue.get(self.fault_cursor) {
            if at > now {
                break;
            }
            // fire everything strictly before the op's (at, card) slot:
            // heap order is (fire, card), so a launch at exactly `at` on
            // a lower-indexed card precedes the op, one on the op's card
            // or higher follows it
            self.advance_calendar(at, card);
            self.fault_cursor += 1;
            self.apply_fault(card, at, op);
        }
        self.advance_calendar(now, usize::MAX);
    }

    /// Pop and fire calendar entries up to the exclusive bound
    /// `(limit, card_bound)` in `(fire, card)` order: entries with
    /// `fire < limit` always fire; entries at `fire == limit` fire only
    /// for cards below `card_bound` (`usize::MAX` ⇒ all of them — the
    /// plain `advance_to(limit)` behaviour).
    fn advance_calendar(&mut self, limit: u64, card_bound: usize) {
        while let Some(&Reverse((fire, i, ep))) = self.calendar.peek() {
            if fire > limit || (fire == limit && i >= card_bound) {
                break;
            }
            self.calendar.pop();
            if ep != self.epoch[i] {
                continue; // stale: the card re-armed since
            }
            self.advance_card_limit(i, limit, i < card_bound);
            self.arm(i);
        }
    }

    /// Fire every launch card `i` would have executed by `now`.
    fn advance_card(&mut self, i: usize, now: u64) {
        self.advance_card_limit(i, now, true);
    }

    /// [`Self::advance_card`] with an exclusive option at the horizon:
    /// with `include_at_now` false, launches due exactly at `now` stay
    /// queued (they sort after a fault op at `(now, card)` in calendar
    /// order and fire once the op has been applied).
    fn advance_card_limit(&mut self, i: usize, now: u64, include_at_now: bool) {
        loop {
            let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) else {
                break;
            };
            if fire > now || (fire == now && !include_at_now) {
                break;
            }
            let Step::Launch(launch) = self.cards[i].step(fire) else {
                unreachable!("fire_at implies a due launch");
            };
            let items = self.cards[i].take_launch(launch, fire);
            // a launch that fires the instant the card frees ran its
            // weight stream during the previous launch (cross-launch
            // prefetch): it pays the warm steady-state cost. A launch
            // into an idle card (or the card's very first) is cold.
            // fire_at never returns a tick before busy_until, so
            // busy_until >= fire means back-to-back.
            let warm = self.busy_until[i] >= fire && self.busy_until[i] > 0;
            // a cold launch under idle gating finds its card power-gated
            // (the router gates every card the instant it idles): the
            // wake-up fill lands before the launch's own stream, a pure
            // serial prefix — the cold-entry analogue the sequence IR
            // already models
            let svc = if warm {
                self.steady_cycles(i, launch)
            } else {
                self.service_cycles(i, launch) + self.wake_cycles(i)
            };
            let start = fire.max(self.busy_until[i]);
            let finish = start + svc;
            self.busy_until[i] = finish;
            self.busy_cycles[i] += svc;
            self.energy_spent_uj += self
                .prices[i]
                .lookup_energy(launch, warm)
                .unwrap_or_else(|| {
                    if warm {
                        self.engines[i].steady_energy_uj(launch)
                    } else {
                        self.engines[i].launch_energy_uj(launch)
                    }
                });
            self.served[i] += items.len() as u64;
            let from = self.completions[i].len();
            for it in items {
                self.completions[i].push(FleetCompletion {
                    idx: it.payload,
                    device: i,
                    class: it.class,
                    arrival: it.enqueued,
                    start,
                    finish,
                });
            }
            // seat order → idx order within the launch, so the card's
            // stream stays (finish, idx)-sorted (finish is strictly
            // increasing across launches: svc ≥ 1)
            self.completions[i][from..].sort_unstable_by_key(|c| c.idx);
        }
        // enqueues and fires both route through here: the cached
        // backlog price tracks every queue-length change
        self.reprice(i);
    }

    /// Apply one fault op to card `i` at cycle `at` (calendar path).
    /// Every card has been advanced to the op's exact calendar slot
    /// before this runs, so retraction sees precisely the launches that
    /// fired before the fault.
    fn apply_fault(&mut self, i: usize, at: u64, op: FaultOp) {
        match op {
            FaultOp::Join => {
                if self.health[i] == CardHealth::Down {
                    self.health[i] = CardHealth::Up;
                    self.net_down -= 1;
                    self.reprice(i);
                }
            }
            FaultOp::DegradeStart(pct) => {
                self.degrade_pct[i] = pct.max(100);
                if self.health[i] == CardHealth::Up {
                    self.health[i] = CardHealth::Degraded;
                }
                // the queue's backlog price depends on the factor
                self.reprice(i);
            }
            FaultOp::DegradeEnd => {
                self.degrade_pct[i] = 100;
                if self.health[i] == CardHealth::Degraded {
                    self.health[i] = CardHealth::Up;
                }
                self.reprice(i);
            }
            FaultOp::Leave => {
                if !self.health[i].pickable() {
                    return; // already down or draining
                }
                self.health[i] = CardHealth::Draining;
                self.net_down += 1;
                // graceful: queued work redistributes (no retry budget
                // consumed), in-flight launches complete normally
                let queued = self.cards[i].drain_all();
                self.reprice(i);
                self.arm(i);
                for it in queued {
                    self.redispatch_one(it.payload, it.class, it.enqueued, at, false);
                }
            }
            FaultOp::Crash => {
                if self.health[i] == CardHealth::Down {
                    return;
                }
                let was_counted = self.health[i].pickable();
                self.health[i] = CardHealth::Down;
                if was_counted {
                    self.net_down += 1;
                }
                // fail-stop: every result that would have finished after
                // `at` is lost. The card's stream is finish-ordered, so
                // the in-flight results are exactly its tail — and that
                // tail is (finish, idx)-sorted, the redispatch order.
                let v = &mut self.completions[i];
                let cut = v.partition_point(|c| c.finish <= at);
                let retracted: Vec<FleetCompletion> = v.split_off(cut);
                self.served[i] -= retracted.len() as u64;
                self.faults.crash_lost += retracted.len() as u64;
                // energy/busy cycles already spent are NOT refunded —
                // the joules went in even though the answers were lost
                self.busy_until[i] = self.busy_until[i].min(at);
                let queued = self.cards[i].drain_all();
                self.reprice(i);
                self.arm(i);
                for c in retracted {
                    self.redispatch_one(c.idx, c.class, c.arrival, at, true);
                }
                for it in queued {
                    self.redispatch_one(it.payload, it.class, it.enqueued, at, false);
                }
            }
        }
    }

    /// Charge one crash-retry against `tag`'s budget. False ⇒ budget
    /// exhausted and the request is counted lost.
    fn consume_retry(&mut self, tag: usize) -> bool {
        let budget = self.plan.as_ref().map_or(0, |p| p.retry_budget);
        let cnt = self.retry_count.entry(tag).or_insert(0);
        if *cnt >= budget {
            self.faults.lost += 1;
            return false;
        }
        *cnt += 1;
        self.faults.retries += 1;
        true
    }

    /// Re-enter one request through the normal assignment path at cycle
    /// `now`, keeping its original class and enqueue tick (the deadline
    /// anchor — an old request is overdue on arrival and boards the next
    /// launch). `budgeted` requests (crash-retracted in-flight work)
    /// consume the retry budget; drained-queue requests do not. A
    /// request whose pick lands on a card that refuses admission is
    /// lost — with the load signals already pricing dead cards at
    /// `u64::MAX`, that only happens when no live card has queue room.
    fn redispatch_one(&mut self, tag: usize, class: Slo, enqueued: u64, now: u64, budgeted: bool) {
        if budgeted && !self.consume_retry(tag) {
            return;
        }
        let j = self.pick(now);
        if !self.admit(j, class) {
            self.faults.lost += 1;
            return;
        }
        self.faults.redispatched += 1;
        self.cards[j].push(tag, class, enqueued);
        self.advance_card(j, now);
        self.arm(j);
    }

    /// Flip fully-drained `Draining` cards to `Down` — the end-of-run
    /// settle (by drain time every in-flight launch has completed).
    /// Gauge-only: both states are equally unpickable.
    fn settle_health(&mut self) {
        if self.plan.is_none() {
            return;
        }
        for h in &mut self.health {
            if *h == CardHealth::Draining {
                *h = CardHealth::Down;
            }
        }
    }

    /// Flush every queue (end of the arrival stream) and take the
    /// completions, ordered by (finish cycle, submission index) — a
    /// k-way merge of the per-card finish-ordered streams (the old path
    /// re-sorted the full run).
    pub fn drain(&mut self) -> Vec<FleetCompletion> {
        self.advance_to(u64::MAX);
        self.settle_health();
        let total: usize = self.completions.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        let mut cursor = vec![0usize; self.completions.len()];
        let mut heads: BinaryHeap<Reverse<(u64, usize, usize)>> = self
            .completions
            .iter()
            .enumerate()
            .filter_map(|(i, v)| v.first().map(|c| Reverse((c.finish, c.idx, i))))
            .collect();
        while let Some(Reverse((_, _, i))) = heads.pop() {
            out.push(self.completions[i][cursor[i]]);
            cursor[i] += 1;
            if let Some(c) = self.completions[i].get(cursor[i]) {
                heads.push(Reverse((c.finish, c.idx, i)));
            }
        }
        for v in &mut self.completions {
            v.clear();
        }
        out
    }

    /// Fold and clear every completion recorded so far **without**
    /// advancing time or ordering across cards — the streaming drain of
    /// the sharded billion-arrival path, whose statistics
    /// ([`FleetStats`]) are order-insensitive by design (materialising
    /// 10⁹ completions is not an option).
    #[doc(hidden)]
    pub fn drain_completed(&mut self, mut f: impl FnMut(&FleetCompletion)) {
        for v in &mut self.completions {
            for c in v.iter() {
                f(c);
            }
            v.clear();
        }
    }

    /// [`Self::drain_completed`], bounded: fold and remove only results
    /// finished by `horizon`, keeping in-flight ones. A later crash can
    /// only retract results finishing after the crash instant, and every
    /// unprocessed fault op fires at or after the current epoch boundary
    /// — so the streaming path must never fold past that boundary, or a
    /// retraction would reach into already-folded statistics.
    #[doc(hidden)]
    pub fn drain_completed_through(&mut self, horizon: u64, mut f: impl FnMut(&FleetCompletion)) {
        for v in &mut self.completions {
            // per-card streams are finish-ordered (see advance_card)
            let cut = v.partition_point(|c| c.finish <= horizon);
            for c in v.drain(..cut) {
                f(&c);
            }
        }
    }

    /// Run a full queued fleet experiment over a class-tagged arrival
    /// stream (seconds, ascending — see [`super::workload`]); returns
    /// one completion per request.
    pub fn run_classed(&mut self, arrivals: &[ClassedArrival]) -> Vec<FleetCompletion> {
        self.reset();
        for a in arrivals {
            let t = (a.t * 1e3 * CYCLES_PER_MS) as u64;
            self.submit_classed(t, a.class);
        }
        self.drain()
    }

    // --- differential oracle (the pre-calendar scan path) ----------------

    /// Reference backlog price: the allocating `decompose` + per-call
    /// `Duration` round-trip the hot path replaced. Kept (with
    /// [`Self::run_classed_scan`]) purely as the oracle the equivalence
    /// suite pins the fast path against — never on a hot path.
    #[doc(hidden)]
    pub fn queued_price_cycles_reference(&self, i: usize, queued: usize) -> u64 {
        decompose(queued, &self.launchable[i])
            .into_iter()
            .map(|b| {
                self.scale_degraded(
                    i,
                    duration_to_cycles(self.engines[i].steady_estimate(b)).max(1),
                )
            })
            .sum()
    }

    /// Reference energy penalty: [`Self::energy_penalty`] recomputed
    /// straight through the engines' energy API instead of the snapshot.
    #[doc(hidden)]
    pub fn energy_penalty_reference(&self, i: usize, idle: bool) -> u64 {
        if self.energy_weight == 0 {
            return 0;
        }
        let uj = if idle && self.cards[i].len() == 0 {
            let &pad = self.launchable[i].last().expect("non-empty ladder");
            self.engines[i].launch_energy_uj(pad)
        } else {
            let &big = self.launchable[i].first().expect("non-empty ladder");
            self.engines[i].steady_energy_uj(big) / big.max(1) as u64
        };
        uj.saturating_mul(self.energy_weight) / 1000
    }

    /// Reference load signal (see [`Self::queued_price_cycles_reference`]).
    #[doc(hidden)]
    pub fn load_cycles_reference(&self, i: usize, now: u64) -> u64 {
        if self.unpickable(i) {
            return u64::MAX;
        }
        let residual = self.busy_until[i].saturating_sub(now);
        match self.load {
            LoadModel::BusyHorizon => residual,
            LoadModel::Backlog | LoadModel::Energy => {
                let n = self.cards[i].len();
                let mut price = residual + self.queued_price_cycles_reference(i, n);
                if residual == 0 && n > 0 {
                    let head = decompose(n, &self.launchable[i])[0];
                    let cold = self.scale_degraded(
                        i,
                        duration_to_cycles(self.engines[i].service_estimate(head)).max(1),
                    );
                    let warm = self.scale_degraded(
                        i,
                        duration_to_cycles(self.engines[i].steady_estimate(head)).max(1),
                    );
                    price += cold.saturating_sub(warm);
                    if self.gate_idle {
                        price += self.engines[i].wakeup_cycles();
                    }
                }
                if self.load == LoadModel::Energy {
                    price = price.saturating_add(self.energy_penalty_reference(i, residual == 0));
                }
                price
            }
        }
    }

    /// The full pre-calendar experiment loop: full-fleet scan per
    /// arrival, per-call `Duration` pricing, one global completion sort.
    /// Differential oracle only — `run_classed` must reproduce its
    /// output bit for bit (asserted in `rust/tests/hotpath_equivalence.rs`).
    #[doc(hidden)]
    pub fn run_classed_scan(&mut self, arrivals: &[ClassedArrival]) -> Vec<FleetCompletion> {
        self.reset();
        let mut comps: Vec<FleetCompletion> = Vec::new();
        let scan = |r: &mut Router, now: u64, comps: &mut Vec<FleetCompletion>| {
            for i in 0..r.engines.len() {
                r.advance_card_scan(i, now, comps);
            }
        };
        for a in arrivals {
            let t = (a.t * 1e3 * CYCLES_PER_MS) as u64;
            self.scan_faults_to(t, &mut comps);
            scan(self, t, &mut comps);
            let i = self.pick_scan(t);
            if !self.admit(i, a.class) {
                self.shed += 1;
                continue;
            }
            let idx = self.submitted;
            self.submitted += 1;
            self.cards[i].push(idx, a.class, t);
            self.advance_card_scan(i, t, &mut comps);
        }
        self.scan_faults_to(u64::MAX, &mut comps);
        scan(self, u64::MAX, &mut comps);
        self.settle_health();
        comps.sort_by_key(|c| (c.finish, c.idx));
        // state parity with `run_classed` after its drain: queues empty,
        // horizons/served kept, calendar empty (the scan never arms it)
        comps
    }

    /// Scan-path fault pump: process every pending fault op at or before
    /// `now`, advancing all cards to each op's exact calendar slot first
    /// (cards below the faulting card include launches firing *at* the
    /// op instant; the faulting card and above do not — the (fire, card)
    /// calendar order, replayed by brute force).
    fn scan_faults_to(&mut self, now: u64, comps: &mut Vec<FleetCompletion>) {
        while let Some(&(at, card, op)) = self.fault_queue.get(self.fault_cursor) {
            if at > now {
                break;
            }
            for j in 0..self.engines.len() {
                self.advance_card_scan_limit(j, at, j < card, comps);
            }
            self.fault_cursor += 1;
            self.apply_fault_scan(card, at, op, comps);
        }
    }

    /// [`Self::apply_fault`] replayed on the scan path: identical health
    /// and ledger transitions, with retraction over the flat completion
    /// list and redispatch through [`Self::pick_scan`].
    fn apply_fault_scan(&mut self, i: usize, at: u64, op: FaultOp, comps: &mut Vec<FleetCompletion>) {
        match op {
            // state-only transitions are path-independent
            FaultOp::Join | FaultOp::DegradeStart(_) | FaultOp::DegradeEnd => {
                self.apply_fault(i, at, op);
            }
            FaultOp::Leave => {
                if !self.health[i].pickable() {
                    return;
                }
                self.health[i] = CardHealth::Draining;
                self.net_down += 1;
                let queued = self.cards[i].drain_all();
                self.reprice(i);
                for it in queued {
                    self.redispatch_one_scan(it.payload, it.class, it.enqueued, at, false, comps);
                }
            }
            FaultOp::Crash => {
                if self.health[i] == CardHealth::Down {
                    return;
                }
                let was_counted = self.health[i].pickable();
                self.health[i] = CardHealth::Down;
                if was_counted {
                    self.net_down += 1;
                }
                let mut retracted: Vec<FleetCompletion> = Vec::new();
                comps.retain(|c| {
                    if c.device == i && c.finish > at {
                        retracted.push(*c);
                        false
                    } else {
                        true
                    }
                });
                // the calendar path retracts the card's finish-ordered
                // suffix, idx-sorted within each launch — i.e. (finish,
                // idx) order (per-card finishes strictly increase)
                retracted.sort_by_key(|c| (c.finish, c.idx));
                self.served[i] -= retracted.len() as u64;
                self.faults.crash_lost += retracted.len() as u64;
                self.busy_until[i] = self.busy_until[i].min(at);
                let queued = self.cards[i].drain_all();
                self.reprice(i);
                for c in retracted {
                    self.redispatch_one_scan(c.idx, c.class, c.arrival, at, true, comps);
                }
                for it in queued {
                    self.redispatch_one_scan(it.payload, it.class, it.enqueued, at, false, comps);
                }
            }
        }
    }

    /// [`Self::redispatch_one`] through the scan-path pick and advance.
    fn redispatch_one_scan(
        &mut self,
        tag: usize,
        class: Slo,
        enqueued: u64,
        now: u64,
        budgeted: bool,
        comps: &mut Vec<FleetCompletion>,
    ) {
        if budgeted && !self.consume_retry(tag) {
            return;
        }
        let j = self.pick_scan(now);
        if !self.admit(j, class) {
            self.faults.lost += 1;
            return;
        }
        self.faults.redispatched += 1;
        self.cards[j].push(tag, class, enqueued);
        self.advance_card_scan(j, now, comps);
    }

    /// Scan-path card advance: identical virtual-time semantics to
    /// [`Self::advance_card`], priced through the engines' `Duration`
    /// API per launch (the old code path, verbatim in spirit).
    fn advance_card_scan(&mut self, i: usize, now: u64, comps: &mut Vec<FleetCompletion>) {
        self.advance_card_scan_limit(i, now, true, comps);
    }

    /// [`Self::advance_card_scan`] with the fault-slot boundary: when
    /// `include_at_now` is false, launches firing exactly at `now` stay
    /// queued (they sit at or after the fault op in calendar order).
    fn advance_card_scan_limit(
        &mut self,
        i: usize,
        now: u64,
        include_at_now: bool,
        comps: &mut Vec<FleetCompletion>,
    ) {
        loop {
            let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) else {
                break;
            };
            if fire > now || (fire == now && !include_at_now) {
                break;
            }
            let Step::Launch(launch) = self.cards[i].step(fire) else {
                unreachable!("fire_at implies a due launch");
            };
            let items = self.cards[i].take_launch(launch, fire);
            let warm = self.busy_until[i] >= fire && self.busy_until[i] > 0;
            let wake = if self.gate_idle {
                self.engines[i].wakeup_cycles()
            } else {
                0
            };
            let svc = if warm {
                self.scale_degraded(
                    i,
                    duration_to_cycles(self.engines[i].steady_estimate(launch)).max(1),
                )
            } else {
                self.scale_degraded(
                    i,
                    duration_to_cycles(self.engines[i].service_estimate(launch)).max(1),
                ) + wake
            };
            let start = fire.max(self.busy_until[i]);
            let finish = start + svc;
            self.busy_until[i] = finish;
            self.busy_cycles[i] += svc;
            self.energy_spent_uj += if warm {
                self.engines[i].steady_energy_uj(launch)
            } else {
                self.engines[i].launch_energy_uj(launch)
            };
            self.served[i] += items.len() as u64;
            for it in items {
                comps.push(FleetCompletion {
                    idx: it.payload,
                    device: i,
                    class: it.class,
                    arrival: it.enqueued,
                    start,
                    finish,
                });
            }
        }
        self.reprice(i); // keep the cache coherent even on the oracle path
    }

    /// Scan-path pick: identical policy logic to [`Self::pick`], load
    /// read through [`Self::load_cycles_reference`].
    fn pick_scan(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => self.pick_round_robin(),
            Policy::LeastLoaded => (0..self.engines.len())
                .min_by_key(|&i| self.load_cycles_reference(i, now))
                .unwrap(),
            Policy::PowerOfTwo => {
                let n = self.engines.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                if self.load_cycles_reference(a, now) <= self.load_cycles_reference(b, now) {
                    a
                } else {
                    b
                }
            }
        }
    }

    // --- legacy immediate-dispatch path ----------------------------------

    /// Route one request arriving at virtual cycle `arrival` (legacy
    /// whole-request dispatch against the busy horizon — no batching).
    pub fn route(&mut self, arrival: u64) -> Routed {
        self.route_batch(arrival, 1)
    }

    /// Route a batched launch of `batch` requests arriving together.
    pub fn route_batch(&mut self, arrival: u64, batch: usize) -> Routed {
        let i = self.pick(arrival);
        // legacy dispatch has no warm tier; the wake fill still only
        // applies when the launch finds the card idle (i.e. gated)
        let wake = if arrival >= self.busy_until[i] {
            self.wake_cycles(i)
        } else {
            0
        };
        let svc = self.service_cycles(i, batch) + wake;
        let start = arrival.max(self.busy_until[i]);
        let finish = start + svc;
        self.busy_until[i] = finish;
        self.busy_cycles[i] += svc;
        self.energy_spent_uj += self
            .prices[i]
            .lookup_energy(batch, false)
            .unwrap_or_else(|| self.engines[i].launch_energy_uj(batch));
        self.index_touch(i); // legacy path skips reprice (queue untouched)
        self.served[i] += batch as u64;
        Routed {
            device: i,
            latency_cycles: finish - arrival,
            queued_cycles: start - arrival,
        }
    }

    /// Run a Poisson arrival experiment: `n` requests at `rate_fps`
    /// offered load; returns per-request latencies in ms.
    pub fn run_poisson(&mut self, n: usize, rate_fps: f64, seed: u64) -> Vec<f64> {
        self.reset();
        let mean_gap_cycles = CYCLES_PER_MS * 1e3 / rate_fps; // 200e6 / rate
        let mut rng = Rng::new(seed);
        let mut t = 0f64;
        let mut lats = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp(mean_gap_cycles);
            let r = self.route(t as u64);
            lats.push(r.latency_cycles as f64 / CYCLES_PER_MS);
        }
        lats
    }

    /// Reset virtual time for a new experiment: busy horizons, queues,
    /// completions, the event calendar, the round-robin cursor AND the
    /// sampling PRNG — back-to-back runs on one router see identical
    /// routing decisions (regression: `next_rr`/`rng` used to survive a
    /// reset, so a second `run_poisson` on the same router was not
    /// reproducible). The batchers keep their shared bucket ladders
    /// ([`CardBatcher::reset`]) — a reset allocates nothing per card
    /// (regression: the old reset re-cloned every engine's ladder).
    pub fn reset(&mut self) {
        self.busy_until.fill(0);
        self.served.fill(0);
        for card in &mut self.cards {
            card.reset();
        }
        for v in &mut self.completions {
            v.clear();
        }
        self.calendar.clear();
        self.epoch.fill(0);
        // queues are empty post-reset, so the backlog cache is all zeros;
        // the bucket-price snapshots stay — they are pure functions of
        // the engines (refresh_prices exists for out-of-band changes)
        self.queue_price.fill(0);
        self.submitted = 0;
        self.shed = 0;
        self.energy_spent_uj = 0;
        self.busy_cycles.fill(0);
        self.next_rr = 0;
        self.rng = Rng::new(ROUTER_SEED);
        // fault runtime (cursor, health, degrade factors, retry ledger,
        // counters) rewinds to the plan's initial state — a faulted run
        // replays bit-identically back to back
        self.fault_runtime_reset();
        // calendar-era audit: the pick index carries per-card keys and
        // heap entries from the previous run — rebuild it alongside the
        // calendar/epochs/prices so back-to-back runs are bit-identical
        self.index_rebuild();
    }

    /// Re-snapshot the per-bucket price caches from the engines. The
    /// router snapshots prices at construction and on [`Self::reset`];
    /// an engine whose estimates change out of band mid-experiment (none
    /// of the shipped engines do on the virtual-time path — `PjrtEngine`
    /// only learns through `run_batch`, which the router never calls)
    /// should be followed by a call to this.
    pub fn refresh_prices(&mut self) {
        for (p, e) in self.prices.iter_mut().zip(&self.engines) {
            *p = CardPrices::snapshot(e.as_ref(), Arc::clone(&p.sizes));
        }
        for i in 0..self.cards.len() {
            self.reprice(i);
        }
    }

    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    /// Requests shed by full per-card queues (queued fleet path).
    pub fn shed_count(&self) -> u64 {
        self.shed
    }

    /// Completed requests per engine.
    pub fn served(&self) -> &[u64] {
        &self.served
    }

    /// Total launch energy dispatched since the last reset, integer µJ
    /// (cold/warm per launch, snapshot-priced — the number the Pareto
    /// experiment divides by completions for J/inference).
    pub fn energy_spent_uj(&self) -> u64 {
        self.energy_spent_uj
    }

    /// Busy cycles dispatched per card since the last reset.
    pub fn busy_cycles(&self) -> &[u64] {
        &self.busy_cycles
    }

    /// Fleet energy over a run of `horizon` virtual cycles, integer µJ:
    /// the dispatched launch energy plus — when idle gating is **off** —
    /// every card's idle draw over its `horizon − busy` cycles
    /// ([`Engine::idle_power_uw`]; µW × cycles / 2·10⁸ cycles-per-second
    /// = µJ, exact integer arithmetic in u128). With gating on, idle
    /// time is power-gated and free; the wake fills it costs were
    /// already charged into the cold launches' latency.
    pub fn fleet_energy_uj(&self, horizon: u64) -> u64 {
        let mut total = self.energy_spent_uj as u128;
        if !self.gate_idle {
            let cps = (CYCLES_PER_MS * 1e3) as u128; // 200e6 cycles/s
            for i in 0..self.engines.len() {
                let idle_cycles = horizon.saturating_sub(self.busy_cycles[i]);
                total += self.prices[i].idle_uw as u128 * idle_cycles as u128 / cps;
            }
        }
        total.min(u64::MAX as u128) as u64
    }
}

// --- sharded router (multi-threaded virtual time) ------------------------

/// Latency histogram bin width for [`FleetStats`]: 0.25 ms of virtual
/// time. Quantiles are exact at this resolution and — unlike a sorted
/// latency vector — the histogram merges commutatively across shards,
/// which is what makes the billion-arrival statistics both streaming
/// and bit-identical for every thread count.
const LAT_BIN_CYCLES: u64 = 50_000;
/// Histogram range: 8192 bins × 0.25 ms = 2048 ms, plus an overflow bin.
const LAT_BINS: usize = 8192;

/// Streaming, mergeable statistics of a sharded fleet run. All fields
/// are integers and every operation is commutative, so the struct is
/// `Eq`-comparable across thread counts and against the scan oracle —
/// the bench's bit-identity assertion is literally `a == b`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetStats {
    /// Offered arrivals (admitted + shed).
    pub arrivals: u64,
    /// Completed requests (== served).
    pub completions: u64,
    /// Requests dropped at full per-card queues.
    pub shed: u64,
    pub sum_latency_cycles: u128,
    pub max_latency_cycles: u64,
    /// Order-insensitive checksum over every completion's (idx, global
    /// device, arrival, start, finish, class) — a wrapping sum of mixed
    /// hashes, so two runs agree iff they produced the same completion
    /// *set*, regardless of fold order.
    pub checksum: u64,
    /// Crash retries charged against per-request budgets.
    pub retries: u64,
    /// Requests successfully re-entered after a crash or drain.
    pub redispatches: u64,
    /// In-flight results retracted by fail-stop crashes.
    pub crash_losses: u64,
    /// Requests lost for good: retry budget exhausted, or no live card
    /// would admit the redispatch.
    pub lost: u64,
    /// End-of-run card health census (Up/Degraded/Draining/Down) —
    /// shards own disjoint cards, so the counts sum across shards.
    pub cards_up: u64,
    pub cards_degraded: u64,
    pub cards_draining: u64,
    pub cards_down: u64,
    hist: Vec<u64>,
}

impl Default for FleetStats {
    fn default() -> Self {
        FleetStats::new()
    }
}

impl FleetStats {
    pub fn new() -> Self {
        FleetStats {
            arrivals: 0,
            completions: 0,
            shed: 0,
            sum_latency_cycles: 0,
            max_latency_cycles: 0,
            checksum: 0,
            retries: 0,
            redispatches: 0,
            crash_losses: 0,
            lost: 0,
            cards_up: 0,
            cards_degraded: 0,
            cards_draining: 0,
            cards_down: 0,
            hist: vec![0; LAT_BINS + 1],
        }
    }

    /// Fold one completion; `device_base` maps the shard-local device
    /// index to its global card id.
    pub fn record(&mut self, c: &FleetCompletion, device_base: usize) {
        self.completions += 1;
        let lat = c.latency_cycles();
        self.sum_latency_cycles += lat as u128;
        self.max_latency_cycles = self.max_latency_cycles.max(lat);
        let bin = ((lat / LAT_BIN_CYCLES) as usize).min(LAT_BINS);
        self.hist[bin] += 1;
        let mut h = mix64(c.idx as u64);
        h = mix64(h ^ (device_base + c.device) as u64);
        h = mix64(h ^ c.arrival);
        h = mix64(h ^ c.start);
        h = mix64(h ^ c.finish);
        h ^= c.class.idx() as u64;
        self.checksum = self.checksum.wrapping_add(mix64(h));
    }

    /// Merge another shard's statistics (commutative + associative).
    pub fn merge(&mut self, o: &FleetStats) {
        self.arrivals += o.arrivals;
        self.completions += o.completions;
        self.shed += o.shed;
        self.sum_latency_cycles += o.sum_latency_cycles;
        self.max_latency_cycles = self.max_latency_cycles.max(o.max_latency_cycles);
        self.checksum = self.checksum.wrapping_add(o.checksum);
        self.retries += o.retries;
        self.redispatches += o.redispatches;
        self.crash_losses += o.crash_losses;
        self.lost += o.lost;
        self.cards_up += o.cards_up;
        self.cards_degraded += o.cards_degraded;
        self.cards_draining += o.cards_draining;
        self.cards_down += o.cards_down;
        for (a, b) in self.hist.iter_mut().zip(&o.hist) {
            *a += b;
        }
    }

    /// q-quantile latency in ms at histogram-bin resolution (the bin's
    /// upper edge; the overflow bin reports the exact tracked maximum).
    /// Rank convention matches [`percentile`]: `round((n-1)·q)`.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.completions == 0 {
            return 0.0;
        }
        let target = ((self.completions as f64 - 1.0) * q).round() as u64;
        let mut seen = 0u64;
        for (b, &n) in self.hist.iter().enumerate() {
            seen += n;
            if seen > target {
                if b == LAT_BINS {
                    break; // overflow bin: report the exact max
                }
                return ((b as u64 + 1) * LAT_BIN_CYCLES) as f64 / CYCLES_PER_MS;
            }
        }
        self.max_latency_cycles as f64 / CYCLES_PER_MS
    }

    pub fn mean_ms(&self) -> f64 {
        if self.completions == 0 {
            return 0.0;
        }
        (self.sum_latency_cycles / self.completions as u128) as f64 / CYCLES_PER_MS
    }
}

/// Sharding knobs: how many shards the cards are partitioned into and
/// the epoch length of the deterministic snapshot-routing clock.
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    /// Card partitions (clamped to [1, cards]). Independent of the
    /// thread count — shards are the unit of determinism, threads only
    /// the unit of execution.
    pub shards: usize,
    /// Epoch length in virtual cycles: shard load summaries refresh at
    /// each (non-empty) epoch's start boundary. Smaller epochs track
    /// load more tightly; larger epochs amortise the per-epoch barrier.
    pub epoch_cycles: u64,
}

impl ShardSpec {
    pub fn new(shards: usize, epoch_ms: f64) -> Self {
        ShardSpec {
            shards,
            epoch_cycles: ((epoch_ms * CYCLES_PER_MS) as u64).max(1),
        }
    }

    /// Auto-tuned shard count: `min(threads, cards)`, floored at 1.
    ///
    /// Balance rule: cards partition into contiguous shards whose sizes
    /// differ by at most one ([`ShardedRouter::with_fleet`]), so any
    /// shard count ≤ cards is load-balanced by construction. More shards
    /// than worker threads buys no parallelism but pays the per-epoch
    /// snapshot barrier per shard; more threads than cards leaves
    /// threads idle. `min(threads, cards)` is therefore the unique
    /// count that saturates both axes — an explicit `--shards` remains
    /// the override for determinism experiments (shards fix the routing
    /// function, threads only the execution).
    pub fn auto(threads: usize, cards: usize, epoch_ms: f64) -> Self {
        ShardSpec::new(threads.min(cards).max(1), epoch_ms)
    }
}

/// One shard: a contiguous card range run by its own [`Router`], plus
/// the per-shard buffers of the phase machinery (reused across epochs —
/// the steady-state hot path allocates nothing per epoch).
struct Shard {
    router: Router,
    /// Global card id of the shard's first card.
    base: usize,
    /// Arrivals assigned to this shard in the current epoch:
    /// (global stream position, arrival cycle, class).
    routed: Vec<(usize, u64, Slo)>,
    /// Stream positions this shard shed (vec-mode idx renumbering).
    shed_pos: Vec<usize>,
    /// Flushed completion stream (vec-mode collect).
    drained: Vec<FleetCompletion>,
    /// Load summary published at the current epoch boundary.
    summary: u64,
    /// Generated-mode substream + its epoch buffer.
    gen: Option<ShardArrivalGen>,
    gen_buf: Vec<(u64, Slo)>,
    stats: FleetStats,
}

impl Shard {
    /// Mean per-card load at `now` — the summary the epoch-snapshot
    /// assignment compares across shards (mean, not sum: shards may
    /// differ in card count by one). Dead/draining cards price at
    /// `u64::MAX` and drop out of the mean — the cross-shard assignment
    /// sees only survivor capacity. A shard with no live card at all
    /// summarises to `u64::MAX` so no arrival is routed its way.
    fn load_summary(&self, now: u64) -> u64 {
        let mut sum = 0u64;
        let mut live = 0u64;
        for i in 0..self.router.engines.len() {
            let l = self.router.load_cycles(i, now);
            if l != u64::MAX {
                sum += l;
                live += 1;
            }
        }
        if live == 0 {
            u64::MAX
        } else {
            sum / live
        }
    }
}

/// A `&mut [Shard]` chunk handed to a scoped worker thread.
///
/// SAFETY: `Shard` fails auto-`Send` only because `Router` erases its
/// engines to `Box<dyn Engine>`. Every engine in a `ShardedRouter` was
/// `Box<dyn Engine + Send>` at construction ([`ShardedRouter::with_fleet`]
/// is the only way to build one), shard routers are never exposed
/// mutably so no non-`Send` engine can enter afterwards, and every
/// other field of `Router`/`Shard` is plain `Send` data — the wrapper
/// restores the `Send` the type erasure hid.
struct SendShards<'a>(&'a mut [Shard]);
unsafe impl Send for SendShards<'_> {}

/// The sharded event-calendar router (see the module docs): cards
/// partitioned into per-shard [`Router`]s executed over
/// [`std::thread::scope`], with epoch-snapshot arrival assignment and a
/// deterministic k-way merge at drain. Results are a pure function of
/// (arrivals, spec) — identical for every `threads` value, and with one
/// shard bit-identical to [`Router::run_classed`].
pub struct ShardedRouter {
    shards: Vec<Shard>,
    epoch_cycles: u64,
    /// Per-shard projected per-arrival load increment: mean warm
    /// batch-1 price over the shard's cards, normalised by card count —
    /// what one more routed arrival adds to the shard's mean load.
    inc: Vec<u64>,
    /// Projected per-shard loads within the current epoch.
    proj: Vec<u64>,
}

impl ShardedRouter {
    /// Partition `engines` into `spec.shards` contiguous card ranges.
    /// Engines must be `Send` — the type-level requirement that makes
    /// handing shards to scoped threads sound.
    pub fn with_fleet(
        engines: Vec<Box<dyn Engine + Send>>,
        policy: Policy,
        fleet: FleetPolicy,
        spec: ShardSpec,
    ) -> Self {
        assert!(!engines.is_empty(), "sharded router needs at least one engine");
        let n = engines.len();
        let shards_n = spec.shards.clamp(1, n);
        let mut shards = Vec::with_capacity(shards_n);
        let mut iter = engines.into_iter();
        let mut base = 0usize;
        for s in 0..shards_n {
            let count = n / shards_n + usize::from(s < n % shards_n);
            let chunk: Vec<Box<dyn Engine>> = (0..count)
                .map(|_| {
                    let e: Box<dyn Engine> = iter.next().expect("sized above");
                    e
                })
                .collect();
            shards.push(Shard {
                router: Router::with_fleet(chunk, policy, fleet),
                base,
                routed: Vec::new(),
                shed_pos: Vec::new(),
                drained: Vec::new(),
                summary: 0,
                gen: None,
                gen_buf: Vec::new(),
                stats: FleetStats::new(),
            });
            base += count;
        }
        let inc = shards
            .iter()
            .map(|sh| {
                let r = &sh.router;
                let cards = r.engines.len() as u64;
                let warm1: u64 = (0..r.engines.len()).map(|i| r.steady_cycles(i, 1)).sum();
                (warm1 / (cards * cards)).max(1)
            })
            .collect();
        ShardedRouter {
            shards,
            epoch_cycles: spec.epoch_cycles.max(1),
            inc,
            proj: vec![0; shards_n],
        }
    }

    /// Builder: switch every shard's JSQ load signal.
    pub fn with_load(mut self, load: LoadModel) -> Self {
        for sh in &mut self.shards {
            sh.router.set_load(load);
        }
        self
    }

    /// Builder: force O(N)-scan least-loaded picks in every shard — the
    /// retained single-threaded oracle of the fleet bench.
    #[doc(hidden)]
    pub fn with_scan_pick(mut self) -> Self {
        for sh in &mut self.shards {
            sh.router.force_scan_pick = true;
        }
        self
    }

    /// Builder: set every shard's [`LoadModel::Energy`] weight
    /// (cycles per mJ; see [`Router::with_energy_weight`]).
    pub fn with_energy_weight(mut self, cycles_per_mj: u64) -> Self {
        for sh in &mut self.shards {
            sh.router.set_energy_weight(cycles_per_mj);
        }
        self
    }

    /// Builder: power-gate idle cards in every shard
    /// (see [`Router::with_idle_gating`]).
    pub fn with_idle_gating(mut self, gate: bool) -> Self {
        for sh in &mut self.shards {
            sh.router.set_idle_gating(gate);
        }
        self
    }

    /// Builder: install a fleet-wide [`FaultPlan`], split along the
    /// contiguous shard boundaries ([`FaultPlan::subplan`]). The plan is
    /// a pure function of (seed, card id), and each shard replays its
    /// slice at exact calendar slots — so the faulted run stays a pure
    /// function of (arrivals, spec, plan), identical for every thread
    /// count, and with one shard bit-identical to the single router.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        assert_eq!(
            plan.cards(),
            self.cards(),
            "fault plan must cover exactly the fleet's cards"
        );
        for sh in &mut self.shards {
            let n = sh.router.engines.len();
            sh.router.set_fault_plan(plan.subplan(sh.base, n));
        }
        self
    }

    /// Fleet-wide fault counters, summed across shards.
    pub fn fault_counters(&self) -> FaultCounters {
        let mut total = FaultCounters::default();
        for sh in &self.shards {
            let c = sh.router.fault_counters();
            total.retries += c.retries;
            total.redispatched += c.redispatched;
            total.crash_lost += c.crash_lost;
            total.lost += c.lost;
        }
        total
    }

    /// Card health census `[up, degraded, draining, down]` across shards.
    pub fn health_counts(&self) -> [u64; 4] {
        let mut total = [0u64; 4];
        for sh in &self.shards {
            let c = sh.router.health_counts();
            for (t, v) in total.iter_mut().zip(c) {
                *t += v;
            }
        }
        total
    }

    /// Total launch energy dispatched across every shard, µJ.
    pub fn energy_spent_uj(&self) -> u64 {
        self.shards.iter().map(|sh| sh.router.energy_spent_uj()).sum()
    }

    /// Fleet energy over `horizon` cycles, summed across shards
    /// (see [`Router::fleet_energy_uj`]).
    pub fn fleet_energy_uj(&self, horizon: u64) -> u64 {
        self.shards
            .iter()
            .map(|sh| sh.router.fleet_energy_uj(horizon))
            .sum()
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn cards(&self) -> usize {
        self.shards.iter().map(|sh| sh.router.engines.len()).sum()
    }

    pub fn shed_count(&self) -> u64 {
        self.shards.iter().map(|sh| sh.router.shed_count()).sum()
    }

    pub fn total_served(&self) -> u64 {
        self.shards.iter().map(|sh| sh.router.total_served()).sum()
    }

    /// Completed requests per global card id.
    pub fn served(&self) -> Vec<u64> {
        self.shards
            .iter()
            .flat_map(|sh| sh.router.served().iter().copied())
            .collect()
    }

    pub fn reset(&mut self) {
        for sh in &mut self.shards {
            sh.router.reset();
            sh.routed.clear();
            sh.shed_pos.clear();
            sh.drained.clear();
            sh.summary = 0;
            sh.gen = None;
            sh.gen_buf.clear();
            sh.stats = FleetStats::new();
        }
        self.proj.fill(0);
    }

    /// Run `f` over every shard — inline for `threads <= 1`, else on
    /// scoped threads over contiguous shard chunks. The chunking is
    /// load-irrelevant: every phase writes only shard-local state, so
    /// the outcome is identical for every thread count by construction.
    fn par_shards<F: Fn(&mut Shard) + Sync>(&mut self, threads: usize, f: F) {
        let threads = threads.max(1).min(self.shards.len());
        if threads == 1 {
            for sh in &mut self.shards {
                f(sh);
            }
            return;
        }
        let per = (self.shards.len() + threads - 1) / threads;
        std::thread::scope(|scope| {
            for chunk in self.shards.chunks_mut(per) {
                let chunk = SendShards(chunk);
                let f = &f;
                scope.spawn(move || {
                    let SendShards(chunk) = chunk;
                    for sh in chunk {
                        f(sh);
                    }
                });
            }
        });
    }

    /// Lowest-index argmin over the projected shard loads — the same
    /// tie-break discipline as the in-shard JSQ pick.
    fn pick_shard(proj: &[u64]) -> usize {
        let mut best = 0usize;
        for s in 1..proj.len() {
            if proj[s] < proj[best] {
                best = s;
            }
        }
        best
    }

    /// Parallel phase: advance every shard to `boundary` and publish its
    /// load summary there.
    fn phase_boundary(&mut self, threads: usize, boundary: u64) {
        self.par_shards(threads, move |sh| {
            sh.router.advance_to(boundary);
            sh.summary = sh.load_summary(boundary);
        });
        for s in 0..self.shards.len() {
            self.proj[s] = self.shards[s].summary;
        }
    }

    /// Parallel phase: every shard submits its assigned arrivals in
    /// stream order. `record_sheds` tracks shed stream positions (vec
    /// mode renumbers admit-order indices from them; the streaming mode
    /// only counts).
    fn phase_process(&mut self, threads: usize, record_sheds: bool) {
        self.par_shards(threads, move |sh| {
            for k in 0..sh.routed.len() {
                let (pos, t, class) = sh.routed[k];
                if sh.router.submit_classed_tagged(t, class, pos).is_none() && record_sheds {
                    sh.shed_pos.push(pos);
                }
            }
            sh.routed.clear();
        });
    }

    /// Run a queued fleet experiment over a class-tagged arrival stream
    /// (seconds, ascending) on `threads` worker threads; returns one
    /// completion per admitted request, (finish, idx)-ordered, with
    /// admit-order indices — for one shard, bit-identical to
    /// [`Router::run_classed`] (asserted in the equivalence suite).
    pub fn run_classed(
        &mut self,
        arrivals: &[ClassedArrival],
        threads: usize,
    ) -> Vec<FleetCompletion> {
        self.reset();
        let e_cycles = self.epoch_cycles;
        let mut pos = 0usize;
        let mut i = 0usize;
        while i < arrivals.len() {
            let t0 = (arrivals[i].t * 1e3 * CYCLES_PER_MS) as u64;
            let epoch = t0 / e_cycles;
            self.phase_boundary(threads, epoch * e_cycles);
            // serial: epoch-snapshot assignment, a pure function of
            // (arrival order, summaries, inc) — never of thread timing
            while i < arrivals.len() {
                let t = (arrivals[i].t * 1e3 * CYCLES_PER_MS) as u64;
                if t / e_cycles != epoch {
                    break;
                }
                let s = Self::pick_shard(&self.proj);
                // saturating: a shard with no live cards summarises to
                // u64::MAX and must stay the unique worst choice
                self.proj[s] = self.proj[s].saturating_add(self.inc[s]);
                self.shards[s].routed.push((pos, t, arrivals[i].class));
                pos += 1;
                i += 1;
            }
            self.phase_process(threads, true);
        }
        self.collect(threads)
    }

    /// Flush every shard and k-way merge the per-shard completion
    /// streams by (finish, idx) — PR 5's per-card merge discipline,
    /// lifted one level — then renumber stream positions to admit-order
    /// indices (`idx' = pos − |{shed positions < pos}|`, a monotone map,
    /// so the merge order is unchanged by it).
    fn collect(&mut self, threads: usize) -> Vec<FleetCompletion> {
        self.par_shards(threads, |sh| {
            let base = sh.base;
            sh.drained = sh.router.drain();
            for c in &mut sh.drained {
                c.device += base;
            }
        });
        let total: usize = self.shards.iter().map(|sh| sh.drained.len()).sum();
        let mut out = Vec::with_capacity(total);
        let mut cursor = vec![0usize; self.shards.len()];
        let mut heads: BinaryHeap<Reverse<(u64, usize, usize)>> = self
            .shards
            .iter()
            .enumerate()
            .filter_map(|(s, sh)| sh.drained.first().map(|c| Reverse((c.finish, c.idx, s))))
            .collect();
        while let Some(Reverse((_, _, s))) = heads.pop() {
            out.push(self.shards[s].drained[cursor[s]]);
            cursor[s] += 1;
            if let Some(c) = self.shards[s].drained.get(cursor[s]) {
                heads.push(Reverse((c.finish, c.idx, s)));
            }
        }
        for sh in &mut self.shards {
            sh.drained.clear();
        }
        let mut sheds: Vec<usize> = self
            .shards
            .iter()
            .flat_map(|sh| sh.shed_pos.iter().copied())
            .collect();
        sheds.sort_unstable();
        if !sheds.is_empty() {
            for c in &mut out {
                c.idx -= sheds.partition_point(|&p| p < c.idx);
            }
        }
        out
    }

    /// The streaming billion-arrival path: one generated substream per
    /// shard ([`ShardArrivalGen`], counter-based — replays exactly for
    /// any thread count), completions folded into per-shard
    /// [`FleetStats`] every epoch instead of materialised. Returns the
    /// merged statistics; identical (`==`) for every `threads` value
    /// and for the [`Self::with_scan_pick`] oracle.
    pub fn run_generated(
        &mut self,
        gens: Vec<ShardArrivalGen>,
        threads: usize,
    ) -> FleetStats {
        assert_eq!(gens.len(), self.shards.len(), "one substream per shard");
        self.reset();
        for (sh, g) in self.shards.iter_mut().zip(gens) {
            sh.gen = Some(g);
        }
        let e_cycles = self.epoch_cycles;
        let mut pos = 0usize;
        let mut epoch = 0u64;
        loop {
            let start = epoch * e_cycles;
            let end = start.saturating_add(e_cycles);
            // parallel: advance to the epoch boundary, publish the load
            // summary, fold finished completions, and pull the
            // substream's arrivals with t < end into the epoch buffer
            self.par_shards(threads, move |sh| {
                sh.router.advance_to(start);
                sh.summary = sh.load_summary(start);
                let Shard { router, stats, base, gen, gen_buf, .. } = sh;
                // fold only results finished by the boundary: a crash at
                // or after `start` may still retract in-flight results,
                // which must not have left the router's ledgers yet
                router.drain_completed_through(start, |c| stats.record(c, *base));
                if let Some(g) = gen {
                    while let Some((t, class)) = g.next_before(end) {
                        gen_buf.push((t, class));
                    }
                }
            });
            for s in 0..self.shards.len() {
                self.proj[s] = self.shards[s].summary;
            }
            // serial: k-way merge the substream buffers by (t, substream)
            // and assign each arrival by the epoch snapshots
            let mut produced = 0usize;
            let mut cursor = vec![0usize; self.shards.len()];
            let mut heads: BinaryHeap<Reverse<(u64, usize)>> = self
                .shards
                .iter()
                .enumerate()
                .filter_map(|(s, sh)| sh.gen_buf.first().map(|&(t, _)| Reverse((t, s))))
                .collect();
            while let Some(Reverse((_, src))) = heads.pop() {
                let (t, class) = self.shards[src].gen_buf[cursor[src]];
                cursor[src] += 1;
                if let Some(&(t2, _)) = self.shards[src].gen_buf.get(cursor[src]) {
                    heads.push(Reverse((t2, src)));
                }
                let s = Self::pick_shard(&self.proj);
                // saturating: a shard with no live cards summarises to
                // u64::MAX and must stay the unique worst choice
                self.proj[s] = self.proj[s].saturating_add(self.inc[s]);
                self.shards[s].routed.push((pos, t, class));
                pos += 1;
                produced += 1;
            }
            for sh in &mut self.shards {
                sh.gen_buf.clear();
            }
            self.phase_process(threads, false);
            epoch += 1;
            let exhausted = self
                .shards
                .iter()
                .all(|sh| sh.gen.as_ref().map_or(true, ShardArrivalGen::done));
            if exhausted && produced == 0 {
                break;
            }
        }
        // flush the tails and merge the per-shard statistics
        self.par_shards(threads, |sh| {
            sh.router.advance_to(u64::MAX);
            sh.router.settle_health();
            let Shard { router, stats, base, .. } = sh;
            router.drain_completed(|c| stats.record(c, *base));
            let fc = router.fault_counters();
            stats.retries += fc.retries;
            stats.redispatches += fc.redispatched;
            stats.crash_losses += fc.crash_lost;
            stats.lost += fc.lost;
            let [up, deg, dr, down] = router.health_counts();
            stats.cards_up += up;
            stats.cards_degraded += deg;
            stats.cards_draining += dr;
            stats.cards_down += down;
            sh.gen = None;
        });
        let mut total = FleetStats::new();
        for sh in &mut self.shards {
            total.merge(&sh.stats);
            sh.stats = FleetStats::new();
        }
        total.arrivals = pos as u64;
        total.shed = self.shed_count();
        total
    }
}

/// The canonical heterogeneous fleet of the PR-3 experiments — 2×Swin-T
/// + 2×Swin-S simulated cards — shared by the acceptance test, the
/// serving benches, the design-space example and `swin-fpga fleet` so
/// they all measure the *same* experiment. One [`CostTable`] per
/// variant: the cards of each variant share it.
pub fn hetero_ts_fleet(cfg: &AccelConfig) -> Vec<Box<dyn Engine>> {
    hetero_ts_fleet_scaled(cfg, 1)
}

/// [`hetero_ts_fleet`] scaled: `scale`× (2×Swin-T + 2×Swin-S) cards
/// behind one router (the hot-path bench runs `scale = 4` → 16 cards).
/// Still one shared [`CostTable`] per variant, whatever the scale.
pub fn hetero_ts_fleet_scaled(cfg: &AccelConfig, scale: usize) -> Vec<Box<dyn Engine>> {
    hetero_ts_fleet_scaled_send(cfg, scale)
        .into_iter()
        .map(|e| {
            let e: Box<dyn Engine> = e;
            e
        })
        .collect()
}

/// [`hetero_ts_fleet_scaled`] with the `Send` bound kept on the trait
/// objects — the form [`ShardedRouter::with_fleet`] requires (the
/// fleet-scale benches run `scale = 64` → 256 cards behind 16 shards).
pub fn hetero_ts_fleet_scaled_send(
    cfg: &AccelConfig,
    scale: usize,
) -> Vec<Box<dyn Engine + Send>> {
    let tiny = Arc::new(CostTable::for_variant(&TINY, cfg.clone(), &BUCKET_SIZES));
    let small = Arc::new(CostTable::for_variant(&SMALL, cfg.clone(), &BUCKET_SIZES));
    let mut engines: Vec<Box<dyn Engine + Send>> = Vec::with_capacity(4 * scale.max(1));
    let mut id = 0;
    for _ in 0..scale.max(1) {
        for (variant, table) in [(&TINY, &tiny), (&TINY, &tiny), (&SMALL, &small), (&SMALL, &small)]
        {
            engines.push(Box::new(SimEngine::with_table(
                id,
                variant,
                Arc::clone(table),
                0.0,
            )));
            id += 1;
        }
    }
    engines
}

/// Aggregate modelled single-image capacity of a fleet in req/s — the
/// scale the experiments set offered load against.
pub fn fleet_capacity_fps(engines: &[Box<dyn Engine>]) -> f64 {
    engines
        .iter()
        .map(|e| 1.0 / e.service_estimate(1).as_secs_f64())
        .sum()
}

/// p-th percentile of a latency vector (ms).
pub fn percentile(lats: &[f64], p: f64) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    let mut v = lats.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() as f64 - 1.0) * p).round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};
    use crate::server::workload::{arrivals, classed_arrivals, Arrival};

    fn router(cards: usize, policy: Policy) -> Router {
        Router::new(cards, &TINY, AccelConfig::paper(), policy)
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = router(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0).device).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_card() {
        let mut r = router(2, Policy::LeastLoaded);
        let a = r.route(0);
        let b = r.route(0);
        assert_ne!(a.device, b.device);
        assert_eq!(b.queued_cycles, 0);
    }

    #[test]
    fn all_requests_served() {
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let lats = r.run_poisson(200, 100.0, 7);
            assert_eq!(lats.len(), 200);
            assert_eq!(r.total_served(), 200);
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }

    #[test]
    fn more_cards_cut_tail_latency_under_overload() {
        // offered 80 FPS vs single-card capacity ~40 FPS: 1 card melts,
        // 4 cards keep the tail bounded
        let mut r1 = router(1, Policy::LeastLoaded);
        let mut r4 = router(4, Policy::LeastLoaded);
        let p99_1 = percentile(&r1.run_poisson(300, 80.0, 1), 0.99);
        let p99_4 = percentile(&r4.run_poisson(300, 80.0, 1), 0.99);
        assert!(
            p99_4 < p99_1 / 3.0,
            "1-card p99 {p99_1:.1} ms vs 4-card {p99_4:.1} ms"
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_under_bursts() {
        // identical arrivals; JSQ should not lose (allow small tie noise)
        let mut rr = router(4, Policy::RoundRobin);
        let mut ll = router(4, Policy::LeastLoaded);
        let p_rr = percentile(&rr.run_poisson(400, 140.0, 3), 0.99);
        let p_ll = percentile(&ll.run_poisson(400, 140.0, 3), 0.99);
        assert!(p_ll <= p_rr * 1.05, "rr {p_rr:.2} vs ll {p_ll:.2}");
    }

    #[test]
    fn heterogeneous_fleet_routes_over_trait_objects() {
        // a TINY card and a MICRO card behind one router: least-loaded
        // steers the bulk of the traffic to the much faster MICRO card
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0)),
            Box::new(SimEngine::new(1, &MICRO, AccelConfig::paper(), 0.0)),
        ];
        let mut r = Router::from_engines(engines, Policy::LeastLoaded);
        let lats = r.run_poisson(200, 100.0, 5);
        assert_eq!(lats.len(), 200);
        assert_eq!(r.total_served(), 200);
        assert!(r.served()[1] > r.served()[0], "served {:?}", r.served());
    }

    #[test]
    fn batched_route_amortises_service_time() {
        let mut r = router(1, Policy::RoundRobin);
        let solo = r.route(0).latency_cycles;
        r.reset();
        let batched = r.route_batch(0, 8).latency_cycles;
        // one 8-launch is far cheaper than eight sequential singles
        assert!(batched < 8 * solo, "batched {batched} vs 8x{solo}");
        assert_eq!(r.total_served(), 8);
    }

    /// Regression (satellite of PR 3): `reset()` used to leave `next_rr`
    /// and the power-of-two sampling rng untouched, so the second of two
    /// back-to-back experiments on one router saw different routing.
    #[test]
    fn reset_makes_back_to_back_runs_reproducible() {
        for policy in [Policy::RoundRobin, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let first = r.run_poisson(200, 120.0, 9);
            let second = r.run_poisson(200, 120.0, 9);
            assert_eq!(first, second, "{:?} diverged after reset", policy.name());
        }
        // queued path too
        let arr = classed_arrivals(Arrival::Poisson { rate: 120.0 }, 200, 0.5, 9);
        let mut r = router(4, Policy::PowerOfTwo);
        let a: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        let b: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, b);
        // faulted path: reset must also restore health, degrade factors,
        // the retry ledger, and the fault cursor (satellite of PR 10)
        let plan = crate::server::fault::FaultPlan::parse(
            "crash:0:150;degrade:1:100:250:400;leave:2:300;join:3:200",
            4,
        )
        .unwrap();
        let mut r = router(4, Policy::PowerOfTwo).with_faults(plan);
        let a = r.run_classed(&arr);
        let ca = r.fault_counters();
        let ha = r.health_counts();
        let b = r.run_classed(&arr);
        assert_completions_identical(&a, &b);
        assert_eq!(ca, r.fault_counters(), "fault counters diverged after reset");
        assert_eq!(ha, r.health_counts(), "health census diverged after reset");
    }

    /// Regression (satellite of PR 3): power-of-two compared raw
    /// `busy_until` values, so a stale horizon from an old burst kept
    /// biasing the choice between two *currently idle* cards.
    #[test]
    fn power_of_two_ignores_stale_horizons() {
        let mut r = router(2, Policy::PowerOfTwo);
        // unbalance the horizons with a burst at t=0
        for _ in 0..20 {
            r.route(0);
        }
        assert_ne!(r.busy_until(0), r.busy_until(1), "burst left unequal horizons");
        // long after both cards went idle the load signal the sampler
        // compares must read zero for both — the old code compared raw
        // `busy_until`, so the card with the smaller stale horizon kept
        // winning every mixed sample between two idle cards
        let far = 10 * r.busy_until(0).max(r.busy_until(1));
        assert_eq!(r.load_cycles(0, far), 0);
        assert_eq!(r.load_cycles(1, far), 0);
        // and with tied (clamped) loads, traffic spread over idle cards
        // follows the uniform sampler rather than the stale horizons
        let before = [r.served()[0], r.served()[1]];
        for k in 0..200u64 {
            r.route(far + k * 1_000_000_000);
        }
        let d0 = r.served()[0] - before[0];
        let d1 = r.served()[1] - before[1];
        assert!(d0 > 0 && d1 > 0, "one idle card starved: split {d0}/{d1}");
    }

    #[test]
    fn queued_fleet_serves_every_request_under_all_policies() {
        let arr = classed_arrivals(Arrival::Poisson { rate: 150.0 }, 300, 0.5, 11);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let comps = r.run_classed(&arr);
            assert_eq!(comps.len(), 300, "{}", policy.name());
            assert_eq!(r.total_served(), 300);
            let mut idx: Vec<usize> = comps.iter().map(|c| c.idx).collect();
            idx.sort_unstable();
            assert_eq!(idx, (0..300).collect::<Vec<_>>());
            for c in &comps {
                assert!(c.finish > c.start && c.start >= c.arrival);
            }
        }
    }

    #[test]
    fn queued_fleet_forms_multi_request_launches() {
        // a concentrated burst must ride shared launches: mean latency
        // far below n × single-launch cost, and served spread over cards
        let ts = arrivals(Arrival::Bursty { high: 2_000.0, burst_s: 0.5, gap_s: 0.1 }, 64, 3);
        let arr: Vec<ClassedArrival> = ts
            .into_iter()
            .map(|t| ClassedArrival { t, class: Slo::Batch })
            .collect();
        let mut r = router(2, Policy::LeastLoaded);
        let svc1 = r.service_cycles(0, 1);
        let svc8 = r.service_cycles(0, 8);
        let comps = r.run_classed(&arr);
        assert_eq!(comps.len(), 64);
        // multi-request launches: completions sharing one (device, start)
        // rode one bucket — the burst must produce full 8-buckets
        let mut groups: std::collections::HashMap<(usize, u64), usize> =
            std::collections::HashMap::new();
        for c in &comps {
            *groups.entry((c.device, c.start)).or_insert(0) += 1;
        }
        assert!(
            groups.values().any(|&n| n >= 8),
            "no full launches formed: {:?}",
            groups.values().collect::<Vec<_>>()
        );
        assert!(svc8 < 8 * svc1, "schedule sanity");
    }

    #[test]
    fn backlog_signal_sees_queued_work_busy_horizon_does_not() {
        let mut r = router(2, Policy::LeastLoaded);
        // 5 requests queued on card 0, none launched (deadline far out,
        // bucket unfilled): busy horizon still reads zero
        let wait = r.fleet.wait_cycles()[1];
        for k in 0..5 {
            r.seed_queue(0, k, Slo::Batch, k as u64);
        }
        assert!(wait > 10, "test assumes a non-trivial batch wait");
        assert_eq!(r.busy_until(0), 0);
        r.load = LoadModel::BusyHorizon;
        assert_eq!(r.load_cycles(0, 5), 0);
        r.load = LoadModel::Backlog;
        let backlog = r.load_cycles(0, 5);
        // priced as decompose(5) = [4, 1]: the head launch finds the
        // card idle and is charged cold, the follower runs back-to-back
        // and is charged its warm (steady-state) cost
        assert_eq!(backlog, r.service_cycles(0, 4) + r.steady_cycles(0, 1));
        assert!(backlog <= r.service_cycles(0, 4) + r.service_cycles(0, 1));
        // the pure warm tier is what queued_price_cycles reports
        assert_eq!(
            r.queued_price_cycles(0, 5),
            r.steady_cycles(0, 4) + r.steady_cycles(0, 1)
        );
        assert_eq!(r.load_cycles(1, 5), 0);
    }

    /// Differential guard (ISSUE 4): the steady-state launch cost the
    /// engines report and the cost the router's backlog pricing charges
    /// for queued work must be the *same number* for every variant ×
    /// bucket — the consumer-drift bug class the PR-3 `service_estimate`
    /// fix addressed, now asserted at the warm tier too.
    #[test]
    fn backlog_pricing_equals_engine_steady_estimates() {
        use crate::model::config::{BASE, MICRO, SMALL};
        for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
            for v in [&MICRO, &TINY, &SMALL, &BASE] {
                let engines: Vec<Box<dyn Engine>> =
                    vec![Box::new(SimEngine::new(0, v, cfg.clone(), 0.0))];
                let r = Router::from_engines(engines, Policy::LeastLoaded);
                for b in [1usize, 2, 4, 8] {
                    let want = duration_to_cycles(r.engines[0].steady_estimate(b)).max(1);
                    assert_eq!(
                        r.queued_price_cycles(0, b),
                        want,
                        "{} b={b} interlaunch={}",
                        v.name,
                        cfg.overlap_interlaunch
                    );
                }
                // a non-bucket queue prices as its greedy decomposition
                assert_eq!(
                    r.queued_price_cycles(0, 13),
                    r.queued_price_cycles(0, 8)
                        + r.queued_price_cycles(0, 4)
                        + r.queued_price_cycles(0, 1)
                );
            }
        }
    }

    /// Back-to-back launches on a busy card run warm (steady-state
    /// cost); a launch into an idle card runs cold. With cross-launch
    /// prefetch disabled the two coincide and the pre-sequence virtual
    /// times are reproduced exactly.
    #[test]
    fn contiguous_launches_pay_the_warm_cost() {
        // full buckets, far-out deadlines: every launch fires the moment
        // the card frees, i.e. back-to-back
        let slam = |cfg: AccelConfig| -> Vec<u64> {
            let engines: Vec<Box<dyn Engine>> =
                vec![Box::new(SimEngine::new(0, &TINY, cfg, 0.0))];
            let fleet = FleetPolicy {
                slo: SloPolicy::uniform(Duration::from_secs(10)),
                ..Default::default()
            };
            let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
            for _ in 0..24 {
                r.submit_classed(0, Slo::Batch);
            }
            let comps = r.drain();
            assert_eq!(comps.len(), 24);
            let mut finishes: Vec<u64> =
                comps.iter().map(|c| c.finish).collect::<Vec<_>>();
            finishes.sort_unstable();
            finishes.dedup();
            finishes
        };
        let warm = slam(AccelConfig::paper());
        let cold = slam(AccelConfig::paper().interlaunch(false));
        assert_eq!(warm.len(), 3, "three batch-8 launches");
        assert_eq!(cold.len(), 3);
        let probe = SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0);
        let c8 = duration_to_cycles(probe.service_estimate(8));
        let w8 = duration_to_cycles(probe.steady_estimate(8));
        assert!(w8 < c8, "warm bucket-8 must be strictly cheaper");
        // first launch cold in both worlds; followers warm only with
        // cross-launch prefetch on
        assert_eq!(warm[0], c8);
        assert_eq!(warm[1], c8 + w8);
        assert_eq!(warm[2], c8 + 2 * w8);
        assert_eq!(cold[2], 3 * c8);
        assert!(warm[2] < cold[2]);
    }

    #[test]
    fn full_card_queues_shed_instead_of_growing_unbounded() {
        // one card, queue_cap 4, deadlines far out: a same-instant slam
        // admits one bucket's worth plus one full queue, sheds the rest
        let engines: Vec<Box<dyn Engine>> =
            vec![Box::new(SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0))];
        let fleet = FleetPolicy {
            queue_cap: 4,
            slo: SloPolicy::uniform(Duration::from_secs(1)),
            ..Default::default()
        };
        let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
        let mut admitted = 0;
        for _ in 0..20 {
            if r.submit_classed(0, Slo::Batch).is_some() {
                admitted += 1;
            }
        }
        // 4 admitted + launched at cap (card was idle), 4 more queued
        // behind the busy card, 12 shed at the full queue
        assert_eq!(admitted, 8, "admitted {admitted}");
        assert_eq!(r.shed_count(), 12);
        let comps = r.drain();
        assert_eq!(comps.len(), 8);
        assert_eq!(r.total_served(), 8);
        assert!(r.queue_depth(0) == 0);
    }

    #[test]
    fn backlog_pricing_respects_fleet_max_batch() {
        // a max_batch below the largest engine bucket: the batcher will
        // never launch an 8, so the backlog price must not assume one.
        // (cold config: at warm steady costs swin-t is compute-bound and
        // 2×steady(4) == steady(8) exactly, so only the cold comparison
        // can witness the lost batch-8 amortisation)
        let cfg = AccelConfig::paper().interlaunch(false);
        let engines: Vec<Box<dyn Engine>> = (0..2)
            .map(|i| Box::new(SimEngine::new(i, &TINY, cfg.clone(), 0.0)) as Box<dyn Engine>)
            .collect();
        let fleet = FleetPolicy {
            max_batch: 4,
            ..Default::default()
        };
        let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
        for k in 0..8 {
            r.seed_queue(0, k, Slo::Batch, 0);
        }
        // two batch-4 launches, not one (cheaper) batch-8 launch
        assert_eq!(r.load_cycles(0, 0), 2 * r.service_cycles(0, 4));
        assert!(r.load_cycles(0, 0) > r.service_cycles(0, 8));
    }

    #[test]
    fn percentile_helper() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }

    fn assert_completions_identical(fast: &[FleetCompletion], slow: &[FleetCompletion]) {
        assert_eq!(fast.len(), slow.len());
        for (f, s) in fast.iter().zip(slow) {
            assert_eq!(
                (f.idx, f.device, f.class, f.arrival, f.start, f.finish),
                (s.idx, s.device, s.class, s.arrival, s.start, s.finish),
                "completion diverged"
            );
        }
    }

    /// The tentpole differential: the event-calendar advance + cached
    /// u64 pricing + k-way-merge drain must reproduce the pre-calendar
    /// full-scan, Duration-priced, globally-sorted path bit for bit —
    /// every policy × load signal, bursty arrivals, homogeneous fleet.
    /// (The heterogeneous / canonical-workload version lives in
    /// `rust/tests/hotpath_equivalence.rs`.)
    #[test]
    fn calendar_router_matches_the_scan_oracle() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            300,
            0.5,
            13,
        );
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
                let mut r = router(3, policy).with_load(load);
                let fast = r.run_classed(&arr);
                let served_fast: Vec<u64> = r.served().to_vec();
                let slow = r.run_classed_scan(&arr);
                assert_completions_identical(&fast, &slow);
                assert_eq!(served_fast, r.served(), "{} {}", policy.name(), load.name());
            }
        }
    }

    // NOTE: the cached-u64-prices == per-call-Duration-reference
    // equivalence (every bucket × queue depth × clock, heterogeneous
    // fleet, seeded queues) lives in the integration suite —
    // rust/tests/hotpath_equivalence.rs — no in-module duplicate.

    /// Calendar hygiene: stale entries are skipped, empty queues arm
    /// nothing, and a drain leaves the calendar reusable.
    #[test]
    fn calendar_survives_reset_and_reuse() {
        let arr = classed_arrivals(Arrival::Poisson { rate: 200.0 }, 150, 0.5, 7);
        let mut r = router(2, Policy::LeastLoaded);
        let a: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        let b: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, b, "calendar state leaked across reset");
        // and interleaving scan runs on the same router changes nothing
        let _ = r.run_classed_scan(&arr);
        let c: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, c);
    }

    /// Reset audit for the calendar era (satellite of this PR): heap,
    /// per-card epochs, price snapshots *and the pick index* must all
    /// come back to the initial state, even across load-model switches
    /// and interleaved oracle runs on the same router.
    #[test]
    fn reset_restores_the_calendar_and_index_across_interleaved_runs() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 400.0, burst_s: 0.2, gap_s: 0.2 },
            250,
            0.5,
            21,
        );
        let mut r = router(4, Policy::LeastLoaded);
        let a = r.run_classed(&arr);
        let _ = r.run_classed_scan(&arr);
        r.set_load(LoadModel::BusyHorizon);
        let _ = r.run_classed(&arr);
        r.set_load(LoadModel::Backlog);
        let b = r.run_classed(&arr);
        assert_completions_identical(&a, &b);
    }

    // --- energy-aware routing & idle gating --------------------------

    /// The tentpole degeneracy: [`LoadModel::Energy`] at weight 0 with
    /// gating off must reproduce [`LoadModel::Backlog`] bit for bit —
    /// every policy, bursty arrivals. (The heterogeneous-fleet version
    /// with the pinned p99s lives in `rust/tests/hotpath_equivalence.rs`.)
    #[test]
    fn energy_model_at_zero_weight_is_backlog_bit_for_bit() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            300,
            0.5,
            13,
        );
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut a = router(3, policy).with_load(LoadModel::Backlog);
            let mut b = router(3, policy)
                .with_load(LoadModel::Energy)
                .with_energy_weight(0)
                .with_idle_gating(false);
            let want = a.run_classed(&arr);
            let got = b.run_classed(&arr);
            assert_completions_identical(&got, &want);
            assert_eq!(a.served(), b.served(), "{}", policy.name());
            assert_eq!(a.energy_spent_uj(), b.energy_spent_uj());
            assert!(a.energy_spent_uj() > 0, "launches must book energy");
        }
    }

    /// The new arms ride the same differential harness as everything
    /// else: energy-weighted and gated runs on the calendar hot path
    /// must reproduce the Duration-priced scan oracle bit for bit (the
    /// per-pick debug assertion additionally pins the O(log N) index
    /// against the O(N) scan throughout).
    #[test]
    fn energy_and_gating_calendar_matches_the_scan_oracle() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            300,
            0.5,
            13,
        );
        for (load, w, gate) in [
            (LoadModel::Energy, 0, true),
            (LoadModel::Energy, 5_000, false),
            (LoadModel::Energy, 5_000, true),
            (LoadModel::Backlog, 0, true),
        ] {
            for policy in [Policy::LeastLoaded, Policy::PowerOfTwo] {
                let mut r = router(3, policy)
                    .with_load(load)
                    .with_energy_weight(w)
                    .with_idle_gating(gate);
                let fast = r.run_classed(&arr);
                let energy_fast = r.energy_spent_uj();
                let slow = r.run_classed_scan(&arr);
                assert_completions_identical(&fast, &slow);
                // snapshot-priced accumulation == engine-priced (scan)
                assert_eq!(
                    energy_fast,
                    r.energy_spent_uj(),
                    "{} w={w} gate={gate}",
                    load.name()
                );
            }
        }
    }

    /// The point of the whole exercise: with a meaningful weight the
    /// energy model steers traffic toward the card with the lower
    /// J/inference. SMALL sits at index 0 so the Backlog tie-break
    /// (lowest index among idle cards) favours the *hungrier* card —
    /// the energy penalty must overcome it, cutting fleet energy.
    #[test]
    fn energy_weight_steers_traffic_to_the_frugal_card() {
        use crate::model::config::SMALL;
        let arr = classed_arrivals(Arrival::Poisson { rate: 40.0 }, 200, 0.5, 11);
        let fleet = || -> Vec<Box<dyn Engine>> {
            vec![
                Box::new(SimEngine::new(0, &SMALL, AccelConfig::paper(), 0.0)),
                Box::new(SimEngine::new(1, &TINY, AccelConfig::paper(), 0.0)),
            ]
        };
        let mut lat = Router::from_engines(fleet(), Policy::LeastLoaded);
        let _ = lat.run_classed(&arr);
        let mut en = Router::from_engines(fleet(), Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(20_000);
        let _ = en.run_classed(&arr);
        assert!(
            en.served()[1] > lat.served()[1],
            "energy routing must shift traffic to TINY: {:?} vs {:?}",
            en.served(),
            lat.served()
        );
        assert!(
            en.energy_spent_uj() < lat.energy_spent_uj(),
            "energy routing must cut launch energy: {} vs {}",
            en.energy_spent_uj(),
            lat.energy_spent_uj()
        );
    }

    /// Idle gating: every cold launch pays exactly the engine's wake-up
    /// fill on top of its cold cost, and in exchange the fleet's idle
    /// draw over the horizon is reclaimed.
    #[test]
    fn idle_gating_charges_wake_and_reclaims_idle_draw() {
        let run = |gate: bool| -> (Vec<FleetCompletion>, u64, Router) {
            let mut r = router(1, Policy::LeastLoaded).with_idle_gating(gate);
            r.submit_classed(0, Slo::Interactive);
            r.submit_classed(1_000_000_000, Slo::Interactive);
            let comps = r.drain();
            let spent = r.energy_spent_uj();
            (comps, spent, r)
        };
        let (plain, spent_plain, plain_r) = run(false);
        let (gated, spent_gated, gated_r) = run(true);
        assert_eq!(plain.len(), 2);
        let wake = plain_r.engines[0].wakeup_cycles();
        assert!(wake > 0);
        // both launches are cold (the card idles between them): each
        // finish slips by exactly the wake fill
        for (p, g) in plain.iter().zip(&gated) {
            assert_eq!(g.finish, p.finish + wake);
            assert_eq!(g.start, p.start);
        }
        // same launches, same launch energy…
        assert_eq!(spent_plain, spent_gated);
        // …but over the horizon the gated fleet reclaims the idle draw
        let horizon = 1_200_000_000;
        assert!(gated_r.fleet_energy_uj(horizon) < plain_r.fleet_energy_uj(horizon));
        // ungated idle billing is exact integer µW-cycles over 2e8
        let idle_uw = plain_r.engines[0].idle_power_uw();
        let idle_cycles = horizon - plain_r.busy_cycles()[0];
        let want = spent_plain as u128 + idle_uw as u128 * idle_cycles as u128 / 200_000_000;
        assert_eq!(plain_r.fleet_energy_uj(horizon) as u128, want);
        assert_eq!(gated_r.fleet_energy_uj(horizon), spent_gated);
    }

    // --- sharded router ---------------------------------------------

    fn send_fleet(cards: usize) -> Vec<Box<dyn Engine + Send>> {
        let table =
            Arc::new(CostTable::for_variant(&TINY, AccelConfig::paper(), &BUCKET_SIZES));
        (0..cards)
            .map(|i| {
                Box::new(SimEngine::with_table(i, &TINY, Arc::clone(&table), 0.0))
                    as Box<dyn Engine + Send>
            })
            .collect()
    }

    fn sharded(cards: usize, shards: usize, policy: Policy) -> ShardedRouter {
        ShardedRouter::with_fleet(
            send_fleet(cards),
            policy,
            FleetPolicy::default(),
            ShardSpec::new(shards, 10.0),
        )
    }

    /// The auto rule: shards = min(threads, cards), floored at 1, and a
    /// default that never exceeds what the balance rule can split evenly
    /// (sizes differ by ≤ 1 for any count ≤ cards).
    #[test]
    fn shard_spec_auto_is_min_threads_cards() {
        assert_eq!(ShardSpec::auto(4, 16, 10.0).shards, 4);
        assert_eq!(ShardSpec::auto(16, 4, 10.0).shards, 4);
        assert_eq!(ShardSpec::auto(8, 8, 10.0).shards, 8);
        assert_eq!(ShardSpec::auto(0, 5, 10.0).shards, 1);
        assert_eq!(ShardSpec::auto(3, 0, 10.0).shards, 1);
        assert_eq!(
            ShardSpec::auto(6, 9, 10.0).epoch_cycles,
            ShardSpec::new(6, 10.0).epoch_cycles
        );
    }

    /// The degeneracy anchor of the whole determinism chain: one shard
    /// on one thread is the event-calendar router, bit for bit — every
    /// policy × load signal (the calendar itself is pinned to the scan
    /// oracle by `calendar_router_matches_the_scan_oracle`).
    #[test]
    fn sharded_single_shard_degenerates_to_the_calendar_router() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            300,
            0.5,
            13,
        );
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
                let mut s = sharded(3, 1, policy).with_load(load);
                let got = s.run_classed(&arr, 1);
                let mut r = router(3, policy).with_load(load);
                let want = r.run_classed(&arr);
                assert_completions_identical(&got, &want);
                assert_eq!(s.served(), r.served().to_vec());
                assert_eq!(s.shed_count(), r.shed_count());
            }
        }
    }

    /// The tentpole invariant: the thread count is execution detail
    /// only — completions, per-card served and shed are identical for
    /// every `threads`, including counts above the shard count. Reusing
    /// one router across the runs also exercises the sharded reset.
    #[test]
    fn sharded_results_identical_for_every_thread_count() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 900.0, burst_s: 0.2, gap_s: 0.2 },
            600,
            0.5,
            17,
        );
        let mut s = sharded(8, 4, Policy::LeastLoaded);
        let base = s.run_classed(&arr, 1);
        let served = s.served();
        let shed = s.shed_count();
        assert_eq!(base.len() as u64 + shed, 600);
        for threads in [2, 3, 4, 8] {
            let got = s.run_classed(&arr, threads);
            assert_completions_identical(&got, &base);
            assert_eq!(s.served(), served, "threads={threads}");
            assert_eq!(s.shed_count(), shed, "threads={threads}");
        }
    }

    /// Under hard overload with tiny queues the sharded path must shed
    /// like the calendar does *and* renumber the surviving stream
    /// positions into dense admit-order indices.
    #[test]
    fn sharded_sheds_and_renumbers_admit_order_indices() {
        let fleet = FleetPolicy { queue_cap: 2, ..FleetPolicy::default() };
        let arr = classed_arrivals(Arrival::Poisson { rate: 4_000.0 }, 400, 0.5, 5);
        let mut s = ShardedRouter::with_fleet(
            send_fleet(4),
            Policy::LeastLoaded,
            fleet,
            ShardSpec::new(2, 5.0),
        );
        let comps = s.run_classed(&arr, 2);
        assert!(s.shed_count() > 0, "overload must shed");
        assert_eq!(comps.len() as u64 + s.shed_count(), 400);
        let mut idx: Vec<usize> = comps.iter().map(|c| c.idx).collect();
        idx.sort_unstable();
        assert_eq!(idx, (0..comps.len()).collect::<Vec<_>>(), "idx not dense");
        let again = s.run_classed(&arr, 1);
        assert_completions_identical(&again, &comps);
    }

    /// The streaming (billion-arrival) mode: merged [`FleetStats`] are
    /// `==` across thread counts and against the O(N)-scan-pick oracle,
    /// and agree with the materialising vec mode on served/shed.
    #[test]
    fn generated_mode_stats_identical_across_threads_and_to_the_oracle() {
        let kind = Arrival::Bursty { high: 120.0, burst_s: 0.2, gap_s: 0.3 };
        let gens = || {
            (0..4u64)
                .map(|s| ShardArrivalGen::new(kind, 500, 0.5, 31, s))
                .collect::<Vec<_>>()
        };
        let mut s = sharded(8, 4, Policy::LeastLoaded);
        let base = s.run_generated(gens(), 1);
        assert_eq!(base.arrivals, 2_000);
        assert_eq!(base.completions + base.shed, base.arrivals);
        assert!(base.quantile_ms(0.99) >= base.quantile_ms(0.5));
        assert!(base.mean_ms() > 0.0);
        for threads in [2, 4] {
            assert_eq!(s.run_generated(gens(), threads), base, "threads={threads}");
        }
        let mut oracle = sharded(8, 4, Policy::LeastLoaded).with_scan_pick();
        assert_eq!(oracle.run_generated(gens(), 2), base, "scan-pick oracle diverged");
    }

    /// Energy-weighted, gated routing through the sharded router: still
    /// a pure function of (arrivals, spec) — identical completions and
    /// energy for every thread count, and with one shard bit-identical
    /// to the calendar router under the same energy configuration.
    #[test]
    fn sharded_energy_routing_is_thread_count_invariant() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 900.0, burst_s: 0.2, gap_s: 0.2 },
            400,
            0.5,
            17,
        );
        let mut s = sharded(8, 4, Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(5_000)
            .with_idle_gating(true);
        let base = s.run_classed(&arr, 1);
        let energy = s.energy_spent_uj();
        assert!(energy > 0);
        for threads in [2, 4] {
            let got = s.run_classed(&arr, threads);
            assert_completions_identical(&got, &base);
            assert_eq!(s.energy_spent_uj(), energy, "threads={threads}");
        }
        let mut one = sharded(3, 1, Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(5_000)
            .with_idle_gating(true);
        let got = one.run_classed(&arr, 1);
        let mut r = router(3, Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(5_000)
            .with_idle_gating(true);
        let want = r.run_classed(&arr);
        assert_completions_identical(&got, &want);
        assert_eq!(one.energy_spent_uj(), r.energy_spent_uj());
        assert_eq!(one.fleet_energy_uj(1 << 32), r.fleet_energy_uj(1 << 32));
    }

    // --- fault injection --------------------------------------------

    use crate::server::fault::ms_to_cycles;

    fn bursty(n: usize, seed: u64) -> Vec<ClassedArrival> {
        classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            n,
            0.5,
            seed,
        )
    }

    /// An installed-but-empty plan must be inert: bit-identical
    /// completions, zero counters, every card up. (The canonical
    /// hetero-fleet pin lives in `rust/tests/hotpath_equivalence.rs`.)
    #[test]
    fn zero_fault_plan_is_inert() {
        let arr = bursty(300, 13);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut plain = router(3, policy);
            let want = plain.run_classed(&arr);
            let mut faulted = router(3, policy).with_faults(FaultPlan::none(3));
            let got = faulted.run_classed(&arr);
            assert_completions_identical(&got, &want);
            assert_eq!(plain.served(), faulted.served(), "{}", policy.name());
            assert_eq!(faulted.fault_counters(), FaultCounters::default());
            assert_eq!(faulted.health_counts(), [3, 0, 0, 0]);
        }
    }

    /// The tentpole differential, faulted: a plan mixing every event
    /// kind must leave the calendar hot path bit-identical to the
    /// Duration-priced scan oracle — completions AND fault counters —
    /// for every policy × load signal.
    #[test]
    fn faulted_calendar_matches_the_scan_oracle() {
        let arr = bursty(300, 13);
        let plan = FaultPlan::parse(
            "crash:0:150;degrade:1:100:250:400;leave:2:300;join:3:200",
            4,
        )
        .unwrap();
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
                let mut r = router(4, policy).with_load(load).with_faults(plan.clone());
                let fast = r.run_classed(&arr);
                let counters = r.fault_counters();
                let shed = r.shed_count();
                let slow = r.run_classed_scan(&arr);
                assert_completions_identical(&fast, &slow);
                assert_eq!(
                    counters,
                    r.fault_counters(),
                    "{} {}",
                    policy.name(),
                    load.name()
                );
                // conservation: every arrival is served, shed, or lost
                assert_eq!(
                    arr.len() as u64,
                    fast.len() as u64 + shed + counters.lost,
                );
            }
        }
    }

    /// Fail-stop crash mid-launch: the in-flight results are retracted
    /// and re-enter routing with their original enqueue ticks, and every
    /// request still completes exactly once on the survivor.
    #[test]
    fn crash_retracts_in_flight_and_redispatches_within_budget() {
        // probe the fault-free run: 8 interactive at t=0 split 4/4, each
        // card launching one batch-4 at the 2 ms flush deadline — take
        // card 0's launch window so the crash lands mid-flight
        let mut probe = router(2, Policy::LeastLoaded);
        for _ in 0..8 {
            probe.submit_classed(0, Slo::Interactive);
        }
        let pc = probe.drain();
        let on0: Vec<_> = pc.iter().filter(|c| c.device == 0).collect();
        assert_eq!(on0.len(), 4, "probe split: {pc:?}");
        let at = (on0[0].start + on0[0].finish) / 2;
        let mut plan = FaultPlan::none(2);
        plan.push(0, FaultEvent::Crash { at });
        let mut r = router(2, Policy::LeastLoaded).with_faults(plan);
        for _ in 0..8 {
            r.submit_classed(0, Slo::Interactive);
        }
        let comps = r.drain();
        let c = r.fault_counters();
        assert_eq!(c.crash_lost, 4, "one in-flight batch-4 lost: {c:?}");
        assert_eq!(c.retries, 4);
        assert_eq!(c.redispatched, 4);
        assert_eq!(c.lost, 0);
        assert_eq!(comps.len(), 8, "every request still completes");
        let mut idx: Vec<usize> = comps.iter().map(|c| c.idx).collect();
        idx.sort_unstable();
        assert_eq!(idx, (0..8).collect::<Vec<_>>(), "exactly once");
        // the survivors keep their original arrival tick (deadline anchor)
        assert!(comps.iter().all(|c| c.arrival == 0));
        assert!(
            comps.iter().all(|c| c.device == 1),
            "card 0's only launch was retracted; everything lands on 1"
        );
        assert_eq!(r.health_counts(), [1, 0, 0, 1]);
    }

    /// With no retry budget (or no live card) crash survivors are lost
    /// and counted — conservation still balances.
    #[test]
    fn exhausted_retry_budget_counts_requests_lost() {
        let mut probe = router(1, Policy::LeastLoaded);
        for _ in 0..4 {
            probe.submit_classed(0, Slo::Interactive);
        }
        let pc = probe.drain();
        let at = (pc[0].start + pc[0].finish) / 2;
        let mut plan = FaultPlan::none(1);
        plan.retry_budget = 0;
        plan.push(0, FaultEvent::Crash { at });
        let mut r = router(1, Policy::LeastLoaded).with_faults(plan);
        for _ in 0..4 {
            r.submit_classed(0, Slo::Interactive);
        }
        let comps = r.drain();
        let c = r.fault_counters();
        assert_eq!(comps.len(), 0);
        assert_eq!(c.crash_lost, 4);
        assert_eq!(c.lost, 4, "budget 0: every survivor is lost");
        assert_eq!(c.retries, 0);
        assert_eq!(4, comps.len() as u64 + r.shed_count() + c.lost);
        assert_eq!(r.health_counts(), [0, 0, 0, 1]);
    }

    /// Graceful leave: queued work redistributes exactly once (no
    /// duplicate, no loss, no retry budget consumed), in-flight work
    /// completes, and the card settles down.
    #[test]
    fn leave_drains_queued_work_exactly_once() {
        let plan = FaultPlan::parse("leave:0:1", 2).unwrap();
        let mut r = router(2, Policy::LeastLoaded).with_faults(plan);
        // Batch-class deadlines are far out: 3 requests sit queued on
        // each card, nothing launches before the leave fires at 1 ms
        for _ in 0..6 {
            r.submit_classed(0, Slo::Batch);
        }
        assert_eq!(r.queue_depth(0), 3);
        let comps = r.drain();
        let c = r.fault_counters();
        assert_eq!(comps.len(), 6, "no request lost in the drain");
        let mut idx: Vec<usize> = comps.iter().map(|c| c.idx).collect();
        idx.sort_unstable();
        assert_eq!(idx, (0..6).collect::<Vec<_>>(), "exactly once");
        assert_eq!(c.redispatched, 3);
        assert_eq!(c.retries, 0, "drain consumes no retry budget");
        assert_eq!(c.lost, 0);
        assert!(comps.iter().all(|c| c.device == 1), "drained to the survivor");
        assert!(comps.iter().all(|c| c.arrival == 0), "enqueue ticks preserved");
        assert_eq!(r.health_counts(), [1, 0, 0, 1], "draining settles to down");
    }

    /// Degrade scales launch compute by factor/100 while active (wake
    /// fill is unscaled, so the slowdown is strictly between 1× and 2×
    /// at factor 200) and the card recovers bit-exactly at `until`.
    #[test]
    fn degrade_slows_launches_then_recovers() {
        let later = ms_to_cycles(5_000.0);
        let mut plain = router(1, Policy::LeastLoaded);
        plain.submit_classed(0, Slo::Interactive);
        plain.submit_classed(later, Slo::Interactive);
        let want = plain.drain();
        let plan = FaultPlan::parse("degrade:0:0:200:1000", 1).unwrap();
        let mut r = router(1, Policy::LeastLoaded).with_faults(plan);
        r.submit_classed(0, Slo::Interactive);
        // a second request far past `until` runs at full speed again
        r.submit_classed(later, Slo::Interactive);
        let comps = r.drain();
        assert_eq!(comps.len(), 2);
        let (svc_p, svc_f) = (want[0].finish - want[0].start, comps[0].finish - comps[0].start);
        assert!(
            svc_f > svc_p && svc_f <= 2 * svc_p,
            "factor 200 scales compute, not wake: plain {svc_p}, degraded {svc_f}"
        );
        assert_eq!(comps[1], want[1], "past `until` the card is bit-identical");
        assert_eq!(r.health_counts(), [1, 0, 0, 0]);
    }

    /// A join-first card is down (unpickable) until its join fires, then
    /// serves traffic.
    #[test]
    fn join_brings_a_spare_card_into_rotation() {
        let plan = FaultPlan::parse("join:1:100", 2).unwrap();
        let mut r = router(2, Policy::RoundRobin).with_faults(plan);
        assert_eq!(r.health(1), CardHealth::Down);
        let arr = bursty(200, 7);
        let comps = r.run_classed(&arr);
        assert!(r.served()[1] > 0, "joined card serves: {:?}", r.served());
        let join_at = ms_to_cycles(100.0);
        assert!(
            comps
                .iter()
                .filter(|c| c.device == 1)
                .all(|c| c.start >= join_at),
            "no launch on the spare before its join"
        );
        assert_eq!(r.health_counts(), [2, 0, 0, 0]);
    }

    /// Sharded faulted runs: thread-count invariant, and with one shard
    /// bit-identical to the calendar router under the same plan
    /// (counters and health census included).
    #[test]
    fn sharded_faulted_runs_are_thread_invariant_and_degenerate() {
        let arr = bursty(400, 17);
        let plan = FaultPlan::parse(
            "crash:1:150;degrade:0:100:220:350;leave:3:250",
            4,
        )
        .unwrap();
        let mut s = sharded(4, 2, Policy::LeastLoaded).with_faults(plan.clone());
        let base = s.run_classed(&arr, 1);
        let counters = s.fault_counters();
        let health = s.health_counts();
        assert!(counters.crash_lost > 0 || counters.redispatched > 0, "{counters:?}");
        for threads in [2, 4] {
            let got = s.run_classed(&arr, threads);
            assert_completions_identical(&got, &base);
            assert_eq!(s.fault_counters(), counters, "threads={threads}");
            assert_eq!(s.health_counts(), health, "threads={threads}");
        }
        let mut one = sharded(4, 1, Policy::LeastLoaded).with_faults(plan.clone());
        let got = one.run_classed(&arr, 1);
        // one shard must degenerate to the plain calendar router
        let mut flat = router(4, Policy::LeastLoaded).with_faults(plan);
        let want = flat.run_classed(&arr);
        assert_completions_identical(&got, &want);
        assert_eq!(one.fault_counters(), flat.fault_counters());
        assert_eq!(one.health_counts(), flat.health_counts());
    }

    /// Streaming (generated) mode under a seeded random plan: merged
    /// stats — fault counters included — are `==` across thread counts
    /// and against the scan-pick oracle, and conservation holds.
    #[test]
    fn generated_mode_faulted_stats_identical_across_threads() {
        let kind = Arrival::Bursty { high: 120.0, burst_s: 0.2, gap_s: 0.3 };
        let gens = || {
            (0..4u64)
                .map(|s| ShardArrivalGen::new(kind, 400, 0.5, 31, s))
                .collect::<Vec<_>>()
        };
        // first seed from 99 whose plan actually schedules faults —
        // robust to FaultPlan::random leaving ~half the cards alone
        let plan = (99..199)
            .map(|s| FaultPlan::random(s, 8, ms_to_cycles(2_000.0), 3))
            .find(|p| !p.is_empty())
            .expect("some seed in 99..199 schedules a fault");
        let mut s = sharded(8, 4, Policy::LeastLoaded).with_faults(plan.clone());
        let base = s.run_generated(gens(), 1);
        assert_eq!(base.arrivals, 1_600);
        assert_eq!(
            base.arrivals,
            base.completions + base.shed + base.lost,
            "conservation"
        );
        assert_eq!(
            base.cards_up + base.cards_degraded + base.cards_draining + base.cards_down,
            8
        );
        for threads in [2, 4] {
            assert_eq!(s.run_generated(gens(), threads), base, "threads={threads}");
        }
        let mut oracle = sharded(8, 4, Policy::LeastLoaded)
            .with_faults(plan)
            .with_scan_pick();
        assert_eq!(oracle.run_generated(gens(), 2), base, "scan-pick oracle diverged");
    }
}
