//! Multi-card router: load-balances inference requests over a fleet of
//! serving [`Engine`]s in virtual time.
//!
//! Policies: round-robin, least-loaded (join-shortest-queue), and a
//! power-of-two-choices sampler — the standard serving trade-off space.
//!
//! Since PR 3 the router runs a **continuous batcher per card**
//! ([`CardBatcher`], the same batch-formation core the wall-clock
//! executor uses): a routed request joins its card's bounded queue, the
//! card forms 8/4/2/1-bucket launches under per-class SLO deadlines
//! ([`SloPolicy`]), and the load signal the JSQ policies compare is the
//! **modelled backlog** — the card's residual busy time plus its queued
//! requests priced through [`decompose`] + [`Engine::service_estimate`]
//! ([`LoadModel::Backlog`]). The pre-batcher signal (raw busy horizon,
//! blind to queued-but-unlaunched work and to per-card speed) is kept as
//! [`LoadModel::BusyHorizon`] for the ablation the fleet experiments
//! report. Either way the estimates bottom out in the pipeline schedule
//! IR ([`crate::accel::pipeline::PipelineSchedule`]): `SimEngine` reads
//! its launch costs from it directly and `PjrtEngine` warms its
//! cold-start estimate from the same schedule until real launches are
//! measured.
//!
//! Since the launch-sequence IR
//! ([`crate::accel::pipeline::SequenceSchedule`]) the router is
//! warm/cold aware: a launch firing the instant its card frees ran its
//! weight stream during the previous launch (cross-launch prefetch) and
//! costs [`Engine::steady_estimate`]; a launch into an idle card pays
//! the cold [`Engine::service_estimate`]. Backlog pricing uses the warm
//! cost for queued work ([`Router::queued_price_cycles`]) — queued
//! launches run back-to-back by construction. With
//! [`crate::accel::AccelConfig::overlap_interlaunch`] off both costs
//! coincide and the pre-sequence behaviour is reproduced exactly.
//!
//! ## The allocation-free hot path
//!
//! The per-arrival **pricing and advance** path does no heap allocation
//! and no `Duration`/`f64` round-trips; the only residual allocations
//! are per *formed launch* (seat selection in
//! [`CardBatcher::take_launch`]) plus amortised container growth —
//! well under one per arrival, vs ~16 decompose `Vec`s per arrival
//! before (`rust/benches/hotpath.rs` tracks both with a counting
//! allocator):
//!
//! * **Event calendar** — virtual time advances through a
//!   [`BinaryHeap`] of per-card next-fire times instead of scanning
//!   every card per arrival (O(M·N) → O(M log N) for M arrivals over N
//!   cards). Stale entries are invalidated by a per-card epoch and
//!   skipped on pop.
//! * **Snapshotted prices** — each card's per-bucket cold/warm launch
//!   prices are converted to `u64` cycles once, at construction/reset
//!   ([`Engine::service_estimate_cycles`]); the backlog price of each
//!   queue is maintained incrementally (recomputed allocation-free from
//!   the queue length on enqueue/launch-fire), so a JSQ pick is pure
//!   integer arithmetic.
//! * **Finish-ordered completion streams** — each card appends its
//!   completions already (finish, idx)-ordered; [`Router::drain`] k-way
//!   merges the per-card streams instead of sorting the whole run.
//!
//! The pre-calendar full-scan advance and per-call `Duration` pricing
//! are retained as a differential oracle ([`Router::run_classed_scan`])
//! — the equivalence suite pins the two paths bit-identical.
//!
//! The single-request [`Router::route`] / [`Router::run_poisson`] path
//! (whole requests dispatched against the busy horizon, no batching) is
//! retained for the legacy scale-out benches.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Duration;

use crate::accel::pipeline::CostTable;
use crate::accel::AccelConfig;
use crate::model::config::{SwinVariant, SMALL, TINY};
use crate::util::prng::Rng;

use super::batcher::{decompose, pick_launch, CardBatcher, Slo, SloPolicy, Step};
use super::engine::{Engine, SimEngine, BUCKET_SIZES};
use super::workload::ClassedArrival;

/// Virtual-time resolution: cycles per millisecond at the paper's
/// 200 MHz accelerator clock (the unit the fleet experiments report in).
pub const CYCLES_PER_MS: f64 = 200_000.0;

/// The router's PRNG seed (power-of-two sampling); [`Router::reset`]
/// restores it so back-to-back experiments on one router are
/// reproducible.
const ROUTER_SEED: u64 = 0xF1EE7;

fn duration_to_cycles(d: Duration) -> u64 {
    (d.as_secs_f64() * 1e3 * CYCLES_PER_MS).round() as u64
}

/// The launch sizes a card's batcher may actually use: its engine
/// buckets capped at `FleetPolicy::max_batch` (falling back to the
/// smallest — padded — bucket when the cap is below all of them), so
/// backlog pricing matches the launches the batcher will run.
fn launchable_sizes(all: &[usize], max_batch: usize) -> Vec<usize> {
    let capped: Vec<usize> = all.iter().copied().filter(|&s| s <= max_batch).collect();
    if capped.is_empty() {
        vec![*all.last().expect("engine has at least one bucket")]
    } else {
        capped
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    PowerOfTwo,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::PowerOfTwo => "power-of-two",
        }
    }
}

/// What load signal the JSQ policies compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadModel {
    /// Residual busy time only (clamped to `now`): blind to queued work
    /// that has not launched yet and to per-card service speed. The
    /// pre-batcher baseline.
    BusyHorizon,
    /// Residual busy time **plus** the card's queue priced through
    /// `decompose` + `service_estimate` — what the card will actually
    /// spend clearing its backlog.
    Backlog,
}

impl LoadModel {
    pub fn name(self) -> &'static str {
        match self {
            LoadModel::BusyHorizon => "busy-horizon",
            LoadModel::Backlog => "backlog",
        }
    }
}

/// Batching knobs of the per-card queues (virtual-time counterpart of
/// [`super::BatchPolicy`]).
#[derive(Debug, Clone, Copy)]
pub struct FleetPolicy {
    pub max_batch: usize,
    /// Per-card admission bound: a request routed to a card whose queue
    /// is full is **shed** (counted by [`Router::shed_count`]), and a
    /// queue at the bound launches immediately instead of waiting out a
    /// deadline — the virtual-time counterpart of the wall-clock
    /// server's bounded channel.
    pub queue_cap: usize,
    /// Per-class flush deadlines.
    pub slo: SloPolicy,
}

impl Default for FleetPolicy {
    fn default() -> Self {
        FleetPolicy {
            max_batch: 8,
            queue_cap: 256,
            slo: SloPolicy::default(),
        }
    }
}

impl FleetPolicy {
    fn wait_cycles(&self) -> [u64; 2] {
        [
            duration_to_cycles(self.slo.interactive_max_wait),
            duration_to_cycles(self.slo.batch_max_wait),
        ]
    }
}

/// Snapshot of one card's per-bucket launch prices in virtual cycles,
/// index-aligned with the engine's full bucket ladder (descending). The
/// conversion from the engine's `Duration` estimates happens exactly
/// once ([`Engine::service_estimate_cycles`], bit-identical to the old
/// per-call round-trip), so the per-arrival loop is pure `u64` work.
#[derive(Debug, Clone)]
struct CardPrices {
    /// The engine's bucket ladder, descending — shared with the card's
    /// batcher (one allocation per distinct ladder in the fleet).
    sizes: Arc<[usize]>,
    /// Cold launch price per ladder entry.
    cold: Vec<u64>,
    /// Warm (steady-state) launch price per ladder entry.
    warm: Vec<u64>,
}

impl CardPrices {
    fn snapshot(e: &dyn Engine, sizes: Arc<[usize]>) -> Self {
        let cold = sizes
            .iter()
            .map(|&b| e.service_estimate_cycles(b, CYCLES_PER_MS).max(1))
            .collect();
        let warm = sizes
            .iter()
            .map(|&b| e.steady_estimate_cycles(b, CYCLES_PER_MS).max(1))
            .collect();
        CardPrices { sizes, cold, warm }
    }

    fn lookup(&self, batch: usize, warm: bool) -> Option<u64> {
        let i = self.sizes.iter().position(|&s| s == batch)?;
        Some(if warm { self.warm[i] } else { self.cold[i] })
    }
}

/// The fleet router.
pub struct Router {
    pub engines: Vec<Box<dyn Engine>>,
    pub policy: Policy,
    /// Load signal for the JSQ policies (see [`LoadModel`]).
    pub load: LoadModel,
    fleet: FleetPolicy,
    /// Per-card continuous-batcher queues (payload: request index).
    cards: Vec<CardBatcher<usize>>,
    /// Per-card launch sizes (engine buckets capped at `max_batch`),
    /// precomputed — backlog pricing runs per arrival on the hot path.
    launchable: Vec<Vec<usize>>,
    /// Per-card bucket-price snapshot (see [`CardPrices`]).
    prices: Vec<CardPrices>,
    /// Cached backlog price of each card's current queue, maintained on
    /// enqueue/launch-fire — a JSQ pick never re-decomposes a queue.
    queue_price: Vec<u64>,
    /// Virtual cycle each engine next goes idle.
    busy_until: Vec<u64>,
    /// Completed requests per engine.
    served: Vec<u64>,
    /// Per-card completion streams, (finish, idx)-ordered by
    /// construction; [`Router::drain`] k-way merges them.
    completions: Vec<Vec<FleetCompletion>>,
    /// Event calendar: `Reverse((next fire, card, epoch))`. Entries are
    /// lazily invalidated — only the card's current epoch is live.
    calendar: BinaryHeap<Reverse<(u64, usize, u64)>>,
    /// Per-card epoch of the live calendar entry.
    epoch: Vec<u64>,
    submitted: usize,
    /// Requests dropped because the picked card's queue was full.
    shed: u64,
    next_rr: usize,
    rng: Rng,
}

/// Result of a routed request (legacy immediate-dispatch path).
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub device: usize,
    pub latency_cycles: u64,
    pub queued_cycles: u64,
}

/// One completed request of a queued fleet experiment.
#[derive(Debug, Clone, Copy)]
pub struct FleetCompletion {
    /// Submission index (position in the arrival stream).
    pub idx: usize,
    pub device: usize,
    pub class: Slo,
    /// Arrival cycle.
    pub arrival: u64,
    /// Cycle its launch started.
    pub start: u64,
    /// Cycle its launch completed.
    pub finish: u64,
}

impl FleetCompletion {
    pub fn latency_cycles(&self) -> u64 {
        self.finish - self.arrival
    }

    /// Queueing + batching wait before the launch started.
    pub fn wait_cycles(&self) -> u64 {
        self.start - self.arrival
    }

    pub fn latency_ms(&self) -> f64 {
        self.latency_cycles() as f64 / CYCLES_PER_MS
    }
}

/// Latencies (ms) of all completions.
pub fn completion_latencies_ms(comps: &[FleetCompletion]) -> Vec<f64> {
    comps.iter().map(FleetCompletion::latency_ms).collect()
}

/// Latencies (ms) of one class's completions.
pub fn class_latencies_ms(comps: &[FleetCompletion], class: Slo) -> Vec<f64> {
    comps
        .iter()
        .filter(|c| c.class == class)
        .map(FleetCompletion::latency_ms)
        .collect()
}

/// Summary percentiles of a fleet experiment — `[p50, p99,
/// interactive p99, batch p99]` in ms (an absent class reports 0) — so
/// the acceptance test, benches, example and CLI all tabulate the same
/// statistics.
pub fn fleet_percentiles(comps: &[FleetCompletion]) -> [f64; 4] {
    let all = completion_latencies_ms(comps);
    [
        percentile(&all, 0.50),
        percentile(&all, 0.99),
        percentile(&class_latencies_ms(comps, Slo::Interactive), 0.99),
        percentile(&class_latencies_ms(comps, Slo::Batch), 0.99),
    ]
}

impl Router {
    /// A homogeneous simulated fleet (the classic fleet experiment):
    /// **one** shared [`CostTable`] — the workload graph is lowered and
    /// the warm costs converged once, then every card reads the same
    /// `Arc` (N× cheaper construction than N independent engines).
    pub fn new(
        cards: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        policy: Policy,
    ) -> Self {
        let table = Arc::new(CostTable::for_variant(variant, cfg, &BUCKET_SIZES));
        Router::from_engines(
            (0..cards)
                .map(|i| {
                    Box::new(SimEngine::with_table(i, variant, Arc::clone(&table), 0.0))
                        as Box<dyn Engine>
                })
                .collect(),
            policy,
        )
    }

    /// Route over any engines — simulated cards, PJRT backends, or a mix.
    pub fn from_engines(engines: Vec<Box<dyn Engine>>, policy: Policy) -> Self {
        Router::with_fleet(engines, policy, FleetPolicy::default())
    }

    /// Full constructor: engines, policy, and per-card batching knobs.
    pub fn with_fleet(
        engines: Vec<Box<dyn Engine>>,
        policy: Policy,
        fleet: FleetPolicy,
    ) -> Self {
        assert!(!engines.is_empty(), "router needs at least one engine");
        let n = engines.len();
        let wait = fleet.wait_cycles();
        // one shared ladder allocation per *distinct* bucket ladder in
        // the fleet (a homogeneous fleet shares a single Arc across its
        // batchers and price snapshots)
        let mut ladders: Vec<Arc<[usize]>> = Vec::new();
        let sizes: Vec<Arc<[usize]>> = engines
            .iter()
            .map(|e| match ladders.iter().find(|l| l.as_ref() == e.batch_sizes()) {
                Some(l) => Arc::clone(l),
                None => {
                    let l: Arc<[usize]> = Arc::from(e.batch_sizes());
                    ladders.push(Arc::clone(&l));
                    l
                }
            })
            .collect();
        let cards = sizes
            .iter()
            .map(|l| CardBatcher::new(Arc::clone(l), fleet.max_batch, fleet.queue_cap, wait))
            .collect();
        let launchable = engines
            .iter()
            .map(|e| launchable_sizes(e.batch_sizes(), fleet.max_batch))
            .collect();
        let prices = engines
            .iter()
            .zip(&sizes)
            .map(|(e, l)| CardPrices::snapshot(e.as_ref(), Arc::clone(l)))
            .collect();
        Router {
            engines,
            policy,
            load: LoadModel::Backlog,
            fleet,
            cards,
            launchable,
            prices,
            queue_price: vec![0; n],
            busy_until: vec![0; n],
            served: vec![0; n],
            completions: vec![Vec::new(); n],
            calendar: BinaryHeap::new(),
            epoch: vec![0; n],
            submitted: 0,
            shed: 0,
            next_rr: 0,
            rng: Rng::new(ROUTER_SEED),
        }
    }

    /// Builder: switch the JSQ load signal (ablations).
    pub fn with_load(mut self, load: LoadModel) -> Self {
        self.load = load;
        self
    }

    /// Virtual cycle at which engine `i` next goes idle.
    pub fn busy_until(&self, i: usize) -> u64 {
        self.busy_until[i]
    }

    /// Requests queued (not yet launched) on card `i`.
    pub fn queue_depth(&self, i: usize) -> usize {
        self.cards[i].len()
    }

    /// Enqueue directly onto card `i` without routing or advancing —
    /// test seeding only; keeps the price cache and calendar coherent.
    #[doc(hidden)]
    pub fn seed_queue(&mut self, i: usize, payload: usize, class: Slo, at: u64) {
        self.cards[i].push(payload, class, at);
        self.submitted = self.submitted.max(payload + 1);
        self.reprice(i);
        self.arm(i);
    }

    /// Cold price of one batch-`batch` launch on card `i`, in cycles:
    /// snapshot lookup for ladder buckets, engine fast path otherwise
    /// (only the legacy arbitrary-batch `route_batch` misses).
    fn service_cycles(&self, i: usize, batch: usize) -> u64 {
        self.prices[i].lookup(batch, false).unwrap_or_else(|| {
            self.engines[i]
                .service_estimate_cycles(batch, CYCLES_PER_MS)
                .max(1)
        })
    }

    /// Warm (steady-state) cost of one more batch-`batch` launch on card
    /// `i` — what a launch actually costs when it starts the moment the
    /// card frees (cross-launch weight prefetch hid its cold entry).
    fn steady_cycles(&self, i: usize, batch: usize) -> u64 {
        self.prices[i].lookup(batch, true).unwrap_or_else(|| {
            self.engines[i]
                .steady_estimate_cycles(batch, CYCLES_PER_MS)
                .max(1)
        })
    }

    /// Price `queued` requests on card `i`: the greedy launch plan the
    /// batcher will run, each launch at its **warm** steady-state cost —
    /// queued work runs back-to-back behind whatever is ahead of it,
    /// which is exactly the regime cross-launch prefetch models. With
    /// `overlap_interlaunch` off the warm and cold estimates coincide
    /// and backlog pricing degenerates to the cold-only form.
    /// ([`Self::load_cycles`] adds the cold-head correction for idle
    /// cards, whose *first* launch cannot have been prefetched.)
    ///
    /// Allocation-free: the greedy largest-fit decomposition is walked
    /// directly over the launchable ladder (division instead of the
    /// repeated-subtraction `Vec` the old path materialised per pick).
    pub fn queued_price_cycles(&self, i: usize, queued: usize) -> u64 {
        let mut rem = queued;
        let mut sum = 0u64;
        for &s in &self.launchable[i] {
            if rem >= s {
                sum += (rem / s) as u64 * self.steady_cycles(i, s);
                rem %= s;
            }
        }
        if rem > 0 {
            // smaller than the smallest launchable size: one padded launch
            let &pad = self.launchable[i].last().expect("non-empty ladder");
            sum += self.steady_cycles(i, pad);
        }
        sum
    }

    /// Refresh card `i`'s cached backlog price (call whenever its queue
    /// length changes — enqueue or launch-fire).
    fn reprice(&mut self, i: usize) {
        self.queue_price[i] = self.queued_price_cycles(i, self.cards[i].len());
    }

    /// The load signal for card `i` at `now`, in cycles of work ahead.
    pub fn load_cycles(&self, i: usize, now: u64) -> u64 {
        let residual = self.busy_until[i].saturating_sub(now);
        match self.load {
            LoadModel::BusyHorizon => residual,
            LoadModel::Backlog => {
                let n = self.cards[i].len();
                debug_assert_eq!(
                    self.queue_price[i],
                    self.queued_price_cycles(i, n),
                    "stale backlog cache on card {i}"
                );
                let mut price = residual + self.queue_price[i];
                if residual == 0 && n > 0 {
                    // the head launch finds an idle card: dispatch will
                    // charge it the cold cost (`advance_card`), so the
                    // signal must too — otherwise idle cards look
                    // (cold − warm) cheaper than busy ones per launch
                    let head = pick_launch(n, &self.launchable[i]);
                    price += self
                        .service_cycles(i, head)
                        .saturating_sub(self.steady_cycles(i, head));
                }
                price
            }
        }
    }

    fn pick(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.engines.len();
                i
            }
            Policy::LeastLoaded => (0..self.engines.len())
                .min_by_key(|&i| self.load_cycles(i, now))
                .unwrap(),
            Policy::PowerOfTwo => {
                let n = self.engines.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                // loads are clamped to `now` (regression: comparing raw
                // `busy_until` let a stale horizon from an old burst bias
                // the choice between two currently idle cards)
                if self.load_cycles(a, now) <= self.load_cycles(b, now) {
                    a
                } else {
                    b
                }
            }
        }
    }

    // --- queued fleet path (per-card continuous batchers) ---------------

    /// Submit one request at virtual cycle `arrival`: pick a card by the
    /// configured load signal and join its batcher queue (launches fire
    /// event-driven as virtual time advances). Returns the card index,
    /// or `None` when the picked card's queue is at `queue_cap` and the
    /// request is shed — the per-card queues are genuinely bounded.
    pub fn submit_classed(&mut self, arrival: u64, class: Slo) -> Option<usize> {
        self.advance_to(arrival);
        let i = self.pick(arrival);
        if self.cards[i].len() >= self.fleet.queue_cap {
            self.shed += 1;
            return None;
        }
        let idx = self.submitted;
        self.submitted += 1;
        self.cards[i].push(idx, class, arrival);
        self.advance_card(i, arrival);
        self.arm(i);
        Some(i)
    }

    /// Re-arm card `i`'s calendar entry from its current queue/busy
    /// state; any older entry for the card is invalidated by the epoch
    /// bump and skipped when popped.
    fn arm(&mut self, i: usize) {
        self.epoch[i] += 1;
        if let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) {
            self.calendar.push(Reverse((fire, i, self.epoch[i])));
        }
    }

    /// Advance virtual time to `now`, firing due launches — via the
    /// event calendar: only cards whose next fire time is due are
    /// touched (the pre-calendar path scanned the whole fleet per
    /// arrival; [`Self::run_classed_scan`] keeps that as the oracle).
    pub fn advance_to(&mut self, now: u64) {
        while let Some(&Reverse((fire, i, ep))) = self.calendar.peek() {
            if fire > now {
                break;
            }
            self.calendar.pop();
            if ep != self.epoch[i] {
                continue; // stale: the card re-armed since
            }
            self.advance_card(i, now);
            self.arm(i);
        }
    }

    /// Fire every launch card `i` would have executed by `now`.
    fn advance_card(&mut self, i: usize, now: u64) {
        loop {
            let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) else {
                break;
            };
            if fire > now {
                break;
            }
            let Step::Launch(launch) = self.cards[i].step(fire) else {
                unreachable!("fire_at implies a due launch");
            };
            let items = self.cards[i].take_launch(launch, fire);
            // a launch that fires the instant the card frees ran its
            // weight stream during the previous launch (cross-launch
            // prefetch): it pays the warm steady-state cost. A launch
            // into an idle card (or the card's very first) is cold.
            // fire_at never returns a tick before busy_until, so
            // busy_until >= fire means back-to-back.
            let warm = self.busy_until[i] >= fire && self.busy_until[i] > 0;
            let svc = if warm {
                self.steady_cycles(i, launch)
            } else {
                self.service_cycles(i, launch)
            };
            let start = fire.max(self.busy_until[i]);
            let finish = start + svc;
            self.busy_until[i] = finish;
            self.served[i] += items.len() as u64;
            let from = self.completions[i].len();
            for it in items {
                self.completions[i].push(FleetCompletion {
                    idx: it.payload,
                    device: i,
                    class: it.class,
                    arrival: it.enqueued,
                    start,
                    finish,
                });
            }
            // seat order → idx order within the launch, so the card's
            // stream stays (finish, idx)-sorted (finish is strictly
            // increasing across launches: svc ≥ 1)
            self.completions[i][from..].sort_unstable_by_key(|c| c.idx);
        }
        // enqueues and fires both route through here: the cached
        // backlog price tracks every queue-length change
        self.reprice(i);
    }

    /// Flush every queue (end of the arrival stream) and take the
    /// completions, ordered by (finish cycle, submission index) — a
    /// k-way merge of the per-card finish-ordered streams (the old path
    /// re-sorted the full run).
    pub fn drain(&mut self) -> Vec<FleetCompletion> {
        self.advance_to(u64::MAX);
        let total: usize = self.completions.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        let mut cursor = vec![0usize; self.completions.len()];
        let mut heads: BinaryHeap<Reverse<(u64, usize, usize)>> = self
            .completions
            .iter()
            .enumerate()
            .filter_map(|(i, v)| v.first().map(|c| Reverse((c.finish, c.idx, i))))
            .collect();
        while let Some(Reverse((_, _, i))) = heads.pop() {
            out.push(self.completions[i][cursor[i]]);
            cursor[i] += 1;
            if let Some(c) = self.completions[i].get(cursor[i]) {
                heads.push(Reverse((c.finish, c.idx, i)));
            }
        }
        for v in &mut self.completions {
            v.clear();
        }
        out
    }

    /// Run a full queued fleet experiment over a class-tagged arrival
    /// stream (seconds, ascending — see [`super::workload`]); returns
    /// one completion per request.
    pub fn run_classed(&mut self, arrivals: &[ClassedArrival]) -> Vec<FleetCompletion> {
        self.reset();
        for a in arrivals {
            let t = (a.t * 1e3 * CYCLES_PER_MS) as u64;
            self.submit_classed(t, a.class);
        }
        self.drain()
    }

    // --- differential oracle (the pre-calendar scan path) ----------------

    /// Reference backlog price: the allocating `decompose` + per-call
    /// `Duration` round-trip the hot path replaced. Kept (with
    /// [`Self::run_classed_scan`]) purely as the oracle the equivalence
    /// suite pins the fast path against — never on a hot path.
    #[doc(hidden)]
    pub fn queued_price_cycles_reference(&self, i: usize, queued: usize) -> u64 {
        decompose(queued, &self.launchable[i])
            .into_iter()
            .map(|b| duration_to_cycles(self.engines[i].steady_estimate(b)).max(1))
            .sum()
    }

    /// Reference load signal (see [`Self::queued_price_cycles_reference`]).
    #[doc(hidden)]
    pub fn load_cycles_reference(&self, i: usize, now: u64) -> u64 {
        let residual = self.busy_until[i].saturating_sub(now);
        match self.load {
            LoadModel::BusyHorizon => residual,
            LoadModel::Backlog => {
                let n = self.cards[i].len();
                let mut price = residual + self.queued_price_cycles_reference(i, n);
                if residual == 0 && n > 0 {
                    let head = decompose(n, &self.launchable[i])[0];
                    let cold = duration_to_cycles(self.engines[i].service_estimate(head)).max(1);
                    let warm = duration_to_cycles(self.engines[i].steady_estimate(head)).max(1);
                    price += cold.saturating_sub(warm);
                }
                price
            }
        }
    }

    /// The full pre-calendar experiment loop: full-fleet scan per
    /// arrival, per-call `Duration` pricing, one global completion sort.
    /// Differential oracle only — `run_classed` must reproduce its
    /// output bit for bit (asserted in `rust/tests/hotpath_equivalence.rs`).
    #[doc(hidden)]
    pub fn run_classed_scan(&mut self, arrivals: &[ClassedArrival]) -> Vec<FleetCompletion> {
        self.reset();
        let mut comps: Vec<FleetCompletion> = Vec::new();
        let scan = |r: &mut Router, now: u64, comps: &mut Vec<FleetCompletion>| {
            for i in 0..r.engines.len() {
                r.advance_card_scan(i, now, comps);
            }
        };
        for a in arrivals {
            let t = (a.t * 1e3 * CYCLES_PER_MS) as u64;
            scan(self, t, &mut comps);
            let i = self.pick_scan(t);
            if self.cards[i].len() >= self.fleet.queue_cap {
                self.shed += 1;
                continue;
            }
            let idx = self.submitted;
            self.submitted += 1;
            self.cards[i].push(idx, a.class, t);
            self.advance_card_scan(i, t, &mut comps);
        }
        scan(self, u64::MAX, &mut comps);
        comps.sort_by_key(|c| (c.finish, c.idx));
        // state parity with `run_classed` after its drain: queues empty,
        // horizons/served kept, calendar empty (the scan never arms it)
        comps
    }

    /// Scan-path card advance: identical virtual-time semantics to
    /// [`Self::advance_card`], priced through the engines' `Duration`
    /// API per launch (the old code path, verbatim in spirit).
    fn advance_card_scan(&mut self, i: usize, now: u64, comps: &mut Vec<FleetCompletion>) {
        loop {
            let Some(fire) = self.cards[i].fire_at(self.busy_until[i]) else {
                break;
            };
            if fire > now {
                break;
            }
            let Step::Launch(launch) = self.cards[i].step(fire) else {
                unreachable!("fire_at implies a due launch");
            };
            let items = self.cards[i].take_launch(launch, fire);
            let warm = self.busy_until[i] >= fire && self.busy_until[i] > 0;
            let svc = if warm {
                duration_to_cycles(self.engines[i].steady_estimate(launch)).max(1)
            } else {
                duration_to_cycles(self.engines[i].service_estimate(launch)).max(1)
            };
            let start = fire.max(self.busy_until[i]);
            let finish = start + svc;
            self.busy_until[i] = finish;
            self.served[i] += items.len() as u64;
            for it in items {
                comps.push(FleetCompletion {
                    idx: it.payload,
                    device: i,
                    class: it.class,
                    arrival: it.enqueued,
                    start,
                    finish,
                });
            }
        }
        self.reprice(i); // keep the cache coherent even on the oracle path
    }

    /// Scan-path pick: identical policy logic to [`Self::pick`], load
    /// read through [`Self::load_cycles_reference`].
    fn pick_scan(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.engines.len();
                i
            }
            Policy::LeastLoaded => (0..self.engines.len())
                .min_by_key(|&i| self.load_cycles_reference(i, now))
                .unwrap(),
            Policy::PowerOfTwo => {
                let n = self.engines.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                if self.load_cycles_reference(a, now) <= self.load_cycles_reference(b, now) {
                    a
                } else {
                    b
                }
            }
        }
    }

    // --- legacy immediate-dispatch path ----------------------------------

    /// Route one request arriving at virtual cycle `arrival` (legacy
    /// whole-request dispatch against the busy horizon — no batching).
    pub fn route(&mut self, arrival: u64) -> Routed {
        self.route_batch(arrival, 1)
    }

    /// Route a batched launch of `batch` requests arriving together.
    pub fn route_batch(&mut self, arrival: u64, batch: usize) -> Routed {
        let i = self.pick(arrival);
        let svc = self.service_cycles(i, batch);
        let start = arrival.max(self.busy_until[i]);
        let finish = start + svc;
        self.busy_until[i] = finish;
        self.served[i] += batch as u64;
        Routed {
            device: i,
            latency_cycles: finish - arrival,
            queued_cycles: start - arrival,
        }
    }

    /// Run a Poisson arrival experiment: `n` requests at `rate_fps`
    /// offered load; returns per-request latencies in ms.
    pub fn run_poisson(&mut self, n: usize, rate_fps: f64, seed: u64) -> Vec<f64> {
        self.reset();
        let mean_gap_cycles = CYCLES_PER_MS * 1e3 / rate_fps; // 200e6 / rate
        let mut rng = Rng::new(seed);
        let mut t = 0f64;
        let mut lats = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp(mean_gap_cycles);
            let r = self.route(t as u64);
            lats.push(r.latency_cycles as f64 / CYCLES_PER_MS);
        }
        lats
    }

    /// Reset virtual time for a new experiment: busy horizons, queues,
    /// completions, the event calendar, the round-robin cursor AND the
    /// sampling PRNG — back-to-back runs on one router see identical
    /// routing decisions (regression: `next_rr`/`rng` used to survive a
    /// reset, so a second `run_poisson` on the same router was not
    /// reproducible). The batchers keep their shared bucket ladders
    /// ([`CardBatcher::reset`]) — a reset allocates nothing per card
    /// (regression: the old reset re-cloned every engine's ladder).
    pub fn reset(&mut self) {
        self.busy_until.fill(0);
        self.served.fill(0);
        for card in &mut self.cards {
            card.reset();
        }
        for v in &mut self.completions {
            v.clear();
        }
        self.calendar.clear();
        self.epoch.fill(0);
        // queues are empty post-reset, so the backlog cache is all zeros;
        // the bucket-price snapshots stay — they are pure functions of
        // the engines (refresh_prices exists for out-of-band changes)
        self.queue_price.fill(0);
        self.submitted = 0;
        self.shed = 0;
        self.next_rr = 0;
        self.rng = Rng::new(ROUTER_SEED);
    }

    /// Re-snapshot the per-bucket price caches from the engines. The
    /// router snapshots prices at construction and on [`Self::reset`];
    /// an engine whose estimates change out of band mid-experiment (none
    /// of the shipped engines do on the virtual-time path — `PjrtEngine`
    /// only learns through `run_batch`, which the router never calls)
    /// should be followed by a call to this.
    pub fn refresh_prices(&mut self) {
        for (p, e) in self.prices.iter_mut().zip(&self.engines) {
            *p = CardPrices::snapshot(e.as_ref(), Arc::clone(&p.sizes));
        }
        for i in 0..self.cards.len() {
            self.reprice(i);
        }
    }

    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    /// Requests shed by full per-card queues (queued fleet path).
    pub fn shed_count(&self) -> u64 {
        self.shed
    }

    /// Completed requests per engine.
    pub fn served(&self) -> &[u64] {
        &self.served
    }
}

/// The canonical heterogeneous fleet of the PR-3 experiments — 2×Swin-T
/// + 2×Swin-S simulated cards — shared by the acceptance test, the
/// serving benches, the design-space example and `swin-fpga fleet` so
/// they all measure the *same* experiment. One [`CostTable`] per
/// variant: the cards of each variant share it.
pub fn hetero_ts_fleet(cfg: &AccelConfig) -> Vec<Box<dyn Engine>> {
    hetero_ts_fleet_scaled(cfg, 1)
}

/// [`hetero_ts_fleet`] scaled: `scale`× (2×Swin-T + 2×Swin-S) cards
/// behind one router (the hot-path bench runs `scale = 4` → 16 cards).
/// Still one shared [`CostTable`] per variant, whatever the scale.
pub fn hetero_ts_fleet_scaled(cfg: &AccelConfig, scale: usize) -> Vec<Box<dyn Engine>> {
    let tiny = Arc::new(CostTable::for_variant(&TINY, cfg.clone(), &BUCKET_SIZES));
    let small = Arc::new(CostTable::for_variant(&SMALL, cfg.clone(), &BUCKET_SIZES));
    let mut engines: Vec<Box<dyn Engine>> = Vec::with_capacity(4 * scale.max(1));
    let mut id = 0;
    for _ in 0..scale.max(1) {
        for (variant, table) in [(&TINY, &tiny), (&TINY, &tiny), (&SMALL, &small), (&SMALL, &small)]
        {
            engines.push(Box::new(SimEngine::with_table(
                id,
                variant,
                Arc::clone(table),
                0.0,
            )));
            id += 1;
        }
    }
    engines
}

/// Aggregate modelled single-image capacity of a fleet in req/s — the
/// scale the experiments set offered load against.
pub fn fleet_capacity_fps(engines: &[Box<dyn Engine>]) -> f64 {
    engines
        .iter()
        .map(|e| 1.0 / e.service_estimate(1).as_secs_f64())
        .sum()
}

/// p-th percentile of a latency vector (ms).
pub fn percentile(lats: &[f64], p: f64) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    let mut v = lats.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() as f64 - 1.0) * p).round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};
    use crate::server::workload::{arrivals, classed_arrivals, Arrival};

    fn router(cards: usize, policy: Policy) -> Router {
        Router::new(cards, &TINY, AccelConfig::paper(), policy)
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = router(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0).device).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_card() {
        let mut r = router(2, Policy::LeastLoaded);
        let a = r.route(0);
        let b = r.route(0);
        assert_ne!(a.device, b.device);
        assert_eq!(b.queued_cycles, 0);
    }

    #[test]
    fn all_requests_served() {
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let lats = r.run_poisson(200, 100.0, 7);
            assert_eq!(lats.len(), 200);
            assert_eq!(r.total_served(), 200);
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }

    #[test]
    fn more_cards_cut_tail_latency_under_overload() {
        // offered 80 FPS vs single-card capacity ~40 FPS: 1 card melts,
        // 4 cards keep the tail bounded
        let mut r1 = router(1, Policy::LeastLoaded);
        let mut r4 = router(4, Policy::LeastLoaded);
        let p99_1 = percentile(&r1.run_poisson(300, 80.0, 1), 0.99);
        let p99_4 = percentile(&r4.run_poisson(300, 80.0, 1), 0.99);
        assert!(
            p99_4 < p99_1 / 3.0,
            "1-card p99 {p99_1:.1} ms vs 4-card {p99_4:.1} ms"
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_under_bursts() {
        // identical arrivals; JSQ should not lose (allow small tie noise)
        let mut rr = router(4, Policy::RoundRobin);
        let mut ll = router(4, Policy::LeastLoaded);
        let p_rr = percentile(&rr.run_poisson(400, 140.0, 3), 0.99);
        let p_ll = percentile(&ll.run_poisson(400, 140.0, 3), 0.99);
        assert!(p_ll <= p_rr * 1.05, "rr {p_rr:.2} vs ll {p_ll:.2}");
    }

    #[test]
    fn heterogeneous_fleet_routes_over_trait_objects() {
        // a TINY card and a MICRO card behind one router: least-loaded
        // steers the bulk of the traffic to the much faster MICRO card
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0)),
            Box::new(SimEngine::new(1, &MICRO, AccelConfig::paper(), 0.0)),
        ];
        let mut r = Router::from_engines(engines, Policy::LeastLoaded);
        let lats = r.run_poisson(200, 100.0, 5);
        assert_eq!(lats.len(), 200);
        assert_eq!(r.total_served(), 200);
        assert!(r.served()[1] > r.served()[0], "served {:?}", r.served());
    }

    #[test]
    fn batched_route_amortises_service_time() {
        let mut r = router(1, Policy::RoundRobin);
        let solo = r.route(0).latency_cycles;
        r.reset();
        let batched = r.route_batch(0, 8).latency_cycles;
        // one 8-launch is far cheaper than eight sequential singles
        assert!(batched < 8 * solo, "batched {batched} vs 8x{solo}");
        assert_eq!(r.total_served(), 8);
    }

    /// Regression (satellite of PR 3): `reset()` used to leave `next_rr`
    /// and the power-of-two sampling rng untouched, so the second of two
    /// back-to-back experiments on one router saw different routing.
    #[test]
    fn reset_makes_back_to_back_runs_reproducible() {
        for policy in [Policy::RoundRobin, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let first = r.run_poisson(200, 120.0, 9);
            let second = r.run_poisson(200, 120.0, 9);
            assert_eq!(first, second, "{:?} diverged after reset", policy.name());
        }
        // queued path too
        let arr = classed_arrivals(Arrival::Poisson { rate: 120.0 }, 200, 0.5, 9);
        let mut r = router(4, Policy::PowerOfTwo);
        let a: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        let b: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, b);
    }

    /// Regression (satellite of PR 3): power-of-two compared raw
    /// `busy_until` values, so a stale horizon from an old burst kept
    /// biasing the choice between two *currently idle* cards.
    #[test]
    fn power_of_two_ignores_stale_horizons() {
        let mut r = router(2, Policy::PowerOfTwo);
        // unbalance the horizons with a burst at t=0
        for _ in 0..20 {
            r.route(0);
        }
        assert_ne!(r.busy_until(0), r.busy_until(1), "burst left unequal horizons");
        // long after both cards went idle the load signal the sampler
        // compares must read zero for both — the old code compared raw
        // `busy_until`, so the card with the smaller stale horizon kept
        // winning every mixed sample between two idle cards
        let far = 10 * r.busy_until(0).max(r.busy_until(1));
        assert_eq!(r.load_cycles(0, far), 0);
        assert_eq!(r.load_cycles(1, far), 0);
        // and with tied (clamped) loads, traffic spread over idle cards
        // follows the uniform sampler rather than the stale horizons
        let before = [r.served()[0], r.served()[1]];
        for k in 0..200u64 {
            r.route(far + k * 1_000_000_000);
        }
        let d0 = r.served()[0] - before[0];
        let d1 = r.served()[1] - before[1];
        assert!(d0 > 0 && d1 > 0, "one idle card starved: split {d0}/{d1}");
    }

    #[test]
    fn queued_fleet_serves_every_request_under_all_policies() {
        let arr = classed_arrivals(Arrival::Poisson { rate: 150.0 }, 300, 0.5, 11);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let comps = r.run_classed(&arr);
            assert_eq!(comps.len(), 300, "{}", policy.name());
            assert_eq!(r.total_served(), 300);
            let mut idx: Vec<usize> = comps.iter().map(|c| c.idx).collect();
            idx.sort_unstable();
            assert_eq!(idx, (0..300).collect::<Vec<_>>());
            for c in &comps {
                assert!(c.finish > c.start && c.start >= c.arrival);
            }
        }
    }

    #[test]
    fn queued_fleet_forms_multi_request_launches() {
        // a concentrated burst must ride shared launches: mean latency
        // far below n × single-launch cost, and served spread over cards
        let ts = arrivals(Arrival::Bursty { high: 2_000.0, burst_s: 0.5, gap_s: 0.1 }, 64, 3);
        let arr: Vec<ClassedArrival> = ts
            .into_iter()
            .map(|t| ClassedArrival { t, class: Slo::Batch })
            .collect();
        let mut r = router(2, Policy::LeastLoaded);
        let svc1 = r.service_cycles(0, 1);
        let svc8 = r.service_cycles(0, 8);
        let comps = r.run_classed(&arr);
        assert_eq!(comps.len(), 64);
        // multi-request launches: completions sharing one (device, start)
        // rode one bucket — the burst must produce full 8-buckets
        let mut groups: std::collections::HashMap<(usize, u64), usize> =
            std::collections::HashMap::new();
        for c in &comps {
            *groups.entry((c.device, c.start)).or_insert(0) += 1;
        }
        assert!(
            groups.values().any(|&n| n >= 8),
            "no full launches formed: {:?}",
            groups.values().collect::<Vec<_>>()
        );
        assert!(svc8 < 8 * svc1, "schedule sanity");
    }

    #[test]
    fn backlog_signal_sees_queued_work_busy_horizon_does_not() {
        let mut r = router(2, Policy::LeastLoaded);
        // 5 requests queued on card 0, none launched (deadline far out,
        // bucket unfilled): busy horizon still reads zero
        let wait = r.fleet.wait_cycles()[1];
        for k in 0..5 {
            r.seed_queue(0, k, Slo::Batch, k as u64);
        }
        assert!(wait > 10, "test assumes a non-trivial batch wait");
        assert_eq!(r.busy_until(0), 0);
        r.load = LoadModel::BusyHorizon;
        assert_eq!(r.load_cycles(0, 5), 0);
        r.load = LoadModel::Backlog;
        let backlog = r.load_cycles(0, 5);
        // priced as decompose(5) = [4, 1]: the head launch finds the
        // card idle and is charged cold, the follower runs back-to-back
        // and is charged its warm (steady-state) cost
        assert_eq!(backlog, r.service_cycles(0, 4) + r.steady_cycles(0, 1));
        assert!(backlog <= r.service_cycles(0, 4) + r.service_cycles(0, 1));
        // the pure warm tier is what queued_price_cycles reports
        assert_eq!(
            r.queued_price_cycles(0, 5),
            r.steady_cycles(0, 4) + r.steady_cycles(0, 1)
        );
        assert_eq!(r.load_cycles(1, 5), 0);
    }

    /// Differential guard (ISSUE 4): the steady-state launch cost the
    /// engines report and the cost the router's backlog pricing charges
    /// for queued work must be the *same number* for every variant ×
    /// bucket — the consumer-drift bug class the PR-3 `service_estimate`
    /// fix addressed, now asserted at the warm tier too.
    #[test]
    fn backlog_pricing_equals_engine_steady_estimates() {
        use crate::model::config::{BASE, MICRO, SMALL};
        for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
            for v in [&MICRO, &TINY, &SMALL, &BASE] {
                let engines: Vec<Box<dyn Engine>> =
                    vec![Box::new(SimEngine::new(0, v, cfg.clone(), 0.0))];
                let r = Router::from_engines(engines, Policy::LeastLoaded);
                for b in [1usize, 2, 4, 8] {
                    let want = duration_to_cycles(r.engines[0].steady_estimate(b)).max(1);
                    assert_eq!(
                        r.queued_price_cycles(0, b),
                        want,
                        "{} b={b} interlaunch={}",
                        v.name,
                        cfg.overlap_interlaunch
                    );
                }
                // a non-bucket queue prices as its greedy decomposition
                assert_eq!(
                    r.queued_price_cycles(0, 13),
                    r.queued_price_cycles(0, 8)
                        + r.queued_price_cycles(0, 4)
                        + r.queued_price_cycles(0, 1)
                );
            }
        }
    }

    /// Back-to-back launches on a busy card run warm (steady-state
    /// cost); a launch into an idle card runs cold. With cross-launch
    /// prefetch disabled the two coincide and the pre-sequence virtual
    /// times are reproduced exactly.
    #[test]
    fn contiguous_launches_pay_the_warm_cost() {
        // full buckets, far-out deadlines: every launch fires the moment
        // the card frees, i.e. back-to-back
        let slam = |cfg: AccelConfig| -> Vec<u64> {
            let engines: Vec<Box<dyn Engine>> =
                vec![Box::new(SimEngine::new(0, &TINY, cfg, 0.0))];
            let fleet = FleetPolicy {
                slo: SloPolicy::uniform(Duration::from_secs(10)),
                ..Default::default()
            };
            let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
            for _ in 0..24 {
                r.submit_classed(0, Slo::Batch);
            }
            let comps = r.drain();
            assert_eq!(comps.len(), 24);
            let mut finishes: Vec<u64> =
                comps.iter().map(|c| c.finish).collect::<Vec<_>>();
            finishes.sort_unstable();
            finishes.dedup();
            finishes
        };
        let warm = slam(AccelConfig::paper());
        let cold = slam(AccelConfig::paper().interlaunch(false));
        assert_eq!(warm.len(), 3, "three batch-8 launches");
        assert_eq!(cold.len(), 3);
        let probe = SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0);
        let c8 = duration_to_cycles(probe.service_estimate(8));
        let w8 = duration_to_cycles(probe.steady_estimate(8));
        assert!(w8 < c8, "warm bucket-8 must be strictly cheaper");
        // first launch cold in both worlds; followers warm only with
        // cross-launch prefetch on
        assert_eq!(warm[0], c8);
        assert_eq!(warm[1], c8 + w8);
        assert_eq!(warm[2], c8 + 2 * w8);
        assert_eq!(cold[2], 3 * c8);
        assert!(warm[2] < cold[2]);
    }

    #[test]
    fn full_card_queues_shed_instead_of_growing_unbounded() {
        // one card, queue_cap 4, deadlines far out: a same-instant slam
        // admits one bucket's worth plus one full queue, sheds the rest
        let engines: Vec<Box<dyn Engine>> =
            vec![Box::new(SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0))];
        let fleet = FleetPolicy {
            queue_cap: 4,
            slo: SloPolicy::uniform(Duration::from_secs(1)),
            ..Default::default()
        };
        let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
        let mut admitted = 0;
        for _ in 0..20 {
            if r.submit_classed(0, Slo::Batch).is_some() {
                admitted += 1;
            }
        }
        // 4 admitted + launched at cap (card was idle), 4 more queued
        // behind the busy card, 12 shed at the full queue
        assert_eq!(admitted, 8, "admitted {admitted}");
        assert_eq!(r.shed_count(), 12);
        let comps = r.drain();
        assert_eq!(comps.len(), 8);
        assert_eq!(r.total_served(), 8);
        assert!(r.queue_depth(0) == 0);
    }

    #[test]
    fn backlog_pricing_respects_fleet_max_batch() {
        // a max_batch below the largest engine bucket: the batcher will
        // never launch an 8, so the backlog price must not assume one.
        // (cold config: at warm steady costs swin-t is compute-bound and
        // 2×steady(4) == steady(8) exactly, so only the cold comparison
        // can witness the lost batch-8 amortisation)
        let cfg = AccelConfig::paper().interlaunch(false);
        let engines: Vec<Box<dyn Engine>> = (0..2)
            .map(|i| Box::new(SimEngine::new(i, &TINY, cfg.clone(), 0.0)) as Box<dyn Engine>)
            .collect();
        let fleet = FleetPolicy {
            max_batch: 4,
            ..Default::default()
        };
        let mut r = Router::with_fleet(engines, Policy::LeastLoaded, fleet);
        for k in 0..8 {
            r.seed_queue(0, k, Slo::Batch, 0);
        }
        // two batch-4 launches, not one (cheaper) batch-8 launch
        assert_eq!(r.load_cycles(0, 0), 2 * r.service_cycles(0, 4));
        assert!(r.load_cycles(0, 0) > r.service_cycles(0, 8));
    }

    #[test]
    fn percentile_helper() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }

    fn assert_completions_identical(fast: &[FleetCompletion], slow: &[FleetCompletion]) {
        assert_eq!(fast.len(), slow.len());
        for (f, s) in fast.iter().zip(slow) {
            assert_eq!(
                (f.idx, f.device, f.class, f.arrival, f.start, f.finish),
                (s.idx, s.device, s.class, s.arrival, s.start, s.finish),
                "completion diverged"
            );
        }
    }

    /// The tentpole differential: the event-calendar advance + cached
    /// u64 pricing + k-way-merge drain must reproduce the pre-calendar
    /// full-scan, Duration-priced, globally-sorted path bit for bit —
    /// every policy × load signal, bursty arrivals, homogeneous fleet.
    /// (The heterogeneous / canonical-workload version lives in
    /// `rust/tests/hotpath_equivalence.rs`.)
    #[test]
    fn calendar_router_matches_the_scan_oracle() {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 500.0, burst_s: 0.2, gap_s: 0.2 },
            300,
            0.5,
            13,
        );
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
                let mut r = router(3, policy).with_load(load);
                let fast = r.run_classed(&arr);
                let served_fast: Vec<u64> = r.served().to_vec();
                let slow = r.run_classed_scan(&arr);
                assert_completions_identical(&fast, &slow);
                assert_eq!(served_fast, r.served(), "{} {}", policy.name(), load.name());
            }
        }
    }

    // NOTE: the cached-u64-prices == per-call-Duration-reference
    // equivalence (every bucket × queue depth × clock, heterogeneous
    // fleet, seeded queues) lives in the integration suite —
    // rust/tests/hotpath_equivalence.rs — no in-module duplicate.

    /// Calendar hygiene: stale entries are skipped, empty queues arm
    /// nothing, and a drain leaves the calendar reusable.
    #[test]
    fn calendar_survives_reset_and_reuse() {
        let arr = classed_arrivals(Arrival::Poisson { rate: 200.0 }, 150, 0.5, 7);
        let mut r = router(2, Policy::LeastLoaded);
        let a: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        let b: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, b, "calendar state leaked across reset");
        // and interleaving scan runs on the same router changes nothing
        let _ = r.run_classed_scan(&arr);
        let c: Vec<u64> = r.run_classed(&arr).iter().map(|c| c.finish).collect();
        assert_eq!(a, c);
    }
}
