//! Multi-card router: load-balances inference requests over a fleet of
//! [`VirtualDevice`] simulated accelerators in virtual time.
//!
//! Policies: round-robin, least-loaded (join-shortest-queue), and a
//! power-of-two-choices sampler — the standard serving trade-off space.
//! The fleet experiment (examples/design_space + e2e bench) reports
//! latency vs offered load per policy and card count.

use crate::accel::device::VirtualDevice;
use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    PowerOfTwo,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::PowerOfTwo => "power-of-two",
        }
    }
}

/// The fleet router.
pub struct Router {
    pub devices: Vec<VirtualDevice>,
    pub policy: Policy,
    next_rr: usize,
    rng: Rng,
}

/// Result of a routed request.
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub device: usize,
    pub latency_cycles: u64,
    pub queued_cycles: u64,
}

impl Router {
    pub fn new(
        cards: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        policy: Policy,
    ) -> Self {
        Router {
            devices: (0..cards)
                .map(|i| VirtualDevice::new(i, variant, cfg.clone()))
                .collect(),
            policy,
            next_rr: 0,
            rng: Rng::new(0xF1EE7),
        }
    }

    fn pick(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.devices.len();
                i
            }
            Policy::LeastLoaded => self
                .devices
                .iter()
                .enumerate()
                .min_by_key(|(_, d)| d.busy_until().max(now))
                .map(|(i, _)| i)
                .unwrap(),
            Policy::PowerOfTwo => {
                let n = self.devices.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                if self.devices[a].busy_until() <= self.devices[b].busy_until() {
                    a
                } else {
                    b
                }
            }
        }
    }

    /// Route one request arriving at virtual cycle `arrival`.
    pub fn route(&mut self, arrival: u64) -> Routed {
        let i = self.pick(arrival);
        let c = self.devices[i].enqueue(arrival);
        Routed {
            device: i,
            latency_cycles: c.finish - arrival,
            queued_cycles: c.queued,
        }
    }

    /// Run a Poisson arrival experiment: `n` requests at `rate_fps`
    /// offered load; returns per-request latencies in ms.
    pub fn run_poisson(&mut self, n: usize, rate_fps: f64, seed: u64) -> Vec<f64> {
        for d in &mut self.devices {
            d.reset();
        }
        let cycles_per_ms = 200_000.0; // at the 200 MHz accelerator clock
        let mean_gap_cycles = cycles_per_ms * 1e3 / rate_fps; // 200e6 / rate
        let mut rng = Rng::new(seed);
        let mut t = 0f64;
        let mut lats = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp(mean_gap_cycles);
            let r = self.route(t as u64);
            lats.push(r.latency_cycles as f64 / cycles_per_ms);
        }
        lats
    }

    pub fn total_served(&self) -> u64 {
        self.devices.iter().map(|d| d.served).sum()
    }
}

/// p-th percentile of a latency vector (ms).
pub fn percentile(lats: &[f64], p: f64) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    let mut v = lats.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() as f64 - 1.0) * p).round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    fn router(cards: usize, policy: Policy) -> Router {
        Router::new(cards, &TINY, AccelConfig::paper(), policy)
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = router(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0).device).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_card() {
        let mut r = router(2, Policy::LeastLoaded);
        let a = r.route(0);
        let b = r.route(0);
        assert_ne!(a.device, b.device);
        assert_eq!(b.queued_cycles, 0);
    }

    #[test]
    fn all_requests_served() {
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let lats = r.run_poisson(200, 100.0, 7);
            assert_eq!(lats.len(), 200);
            assert_eq!(r.total_served(), 200);
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }

    #[test]
    fn more_cards_cut_tail_latency_under_overload() {
        // offered 80 FPS vs single-card capacity ~40 FPS: 1 card melts,
        // 4 cards keep the tail bounded
        let mut r1 = router(1, Policy::LeastLoaded);
        let mut r4 = router(4, Policy::LeastLoaded);
        let p99_1 = percentile(&r1.run_poisson(300, 80.0, 1), 0.99);
        let p99_4 = percentile(&r4.run_poisson(300, 80.0, 1), 0.99);
        assert!(
            p99_4 < p99_1 / 3.0,
            "1-card p99 {p99_1:.1} ms vs 4-card {p99_4:.1} ms"
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_under_bursts() {
        // identical arrivals; JSQ should not lose (allow small tie noise)
        let mut rr = router(4, Policy::RoundRobin);
        let mut ll = router(4, Policy::LeastLoaded);
        let p_rr = percentile(&rr.run_poisson(400, 140.0, 3), 0.99);
        let p_ll = percentile(&ll.run_poisson(400, 140.0, 3), 0.99);
        assert!(p_ll <= p_rr * 1.05, "rr {p_rr:.2} vs ll {p_ll:.2}");
    }

    #[test]
    fn percentile_helper() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }
}
