//! Multi-card router: load-balances inference requests over a fleet of
//! serving [`Engine`]s in virtual time.
//!
//! Policies: round-robin, least-loaded (join-shortest-queue), and a
//! power-of-two-choices sampler — the standard serving trade-off space.
//! The router keeps per-engine busy horizons in virtual cycles (derived
//! from each engine's [`Engine::service_estimate`]), so the fleet
//! experiments (examples/design_space + the e2e/fleet benches) run
//! identically over simulated cards and PJRT-backed engines. Either way
//! the estimates bottom out in the pipeline schedule IR
//! ([`crate::accel::pipeline::PipelineSchedule`]): `SimEngine` reads its
//! launch costs from it directly and `PjrtEngine` warms its cold-start
//! estimate from the same schedule until real launches are measured.

use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::util::prng::Rng;

use super::engine::{Engine, SimEngine};

/// Virtual-time resolution: cycles per millisecond at the paper's
/// 200 MHz accelerator clock (the unit the fleet experiments report in).
pub const CYCLES_PER_MS: f64 = 200_000.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    PowerOfTwo,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::PowerOfTwo => "power-of-two",
        }
    }
}

/// The fleet router.
pub struct Router {
    pub engines: Vec<Box<dyn Engine>>,
    pub policy: Policy,
    /// Virtual cycle each engine next goes idle.
    busy_until: Vec<u64>,
    /// Completed requests per engine.
    served: Vec<u64>,
    next_rr: usize,
    rng: Rng,
}

/// Result of a routed request.
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub device: usize,
    pub latency_cycles: u64,
    pub queued_cycles: u64,
}

impl Router {
    /// A homogeneous simulated fleet (the classic fleet experiment).
    pub fn new(
        cards: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        policy: Policy,
    ) -> Self {
        Router::from_engines(
            (0..cards)
                .map(|i| {
                    Box::new(SimEngine::new(i, variant, cfg.clone(), 0.0)) as Box<dyn Engine>
                })
                .collect(),
            policy,
        )
    }

    /// Route over any engines — simulated cards, PJRT backends, or a mix.
    pub fn from_engines(engines: Vec<Box<dyn Engine>>, policy: Policy) -> Self {
        assert!(!engines.is_empty(), "router needs at least one engine");
        let n = engines.len();
        Router {
            engines,
            policy,
            busy_until: vec![0; n],
            served: vec![0; n],
            next_rr: 0,
            rng: Rng::new(0xF1EE7),
        }
    }

    /// Virtual cycle at which engine `i` next goes idle.
    pub fn busy_until(&self, i: usize) -> u64 {
        self.busy_until[i]
    }

    fn service_cycles(&self, i: usize, batch: usize) -> u64 {
        let est = self.engines[i].service_estimate(batch);
        (est.as_secs_f64() * 1e3 * CYCLES_PER_MS).round().max(1.0) as u64
    }

    fn pick(&mut self, now: u64) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.engines.len();
                i
            }
            Policy::LeastLoaded => (0..self.engines.len())
                .min_by_key(|&i| self.busy_until[i].max(now))
                .unwrap(),
            Policy::PowerOfTwo => {
                let n = self.engines.len() as u64;
                let a = self.rng.below(n) as usize;
                let b = self.rng.below(n) as usize;
                if self.busy_until[a] <= self.busy_until[b] {
                    a
                } else {
                    b
                }
            }
        }
    }

    /// Route one request arriving at virtual cycle `arrival`.
    pub fn route(&mut self, arrival: u64) -> Routed {
        self.route_batch(arrival, 1)
    }

    /// Route a batched launch of `batch` requests arriving together.
    pub fn route_batch(&mut self, arrival: u64, batch: usize) -> Routed {
        let i = self.pick(arrival);
        let svc = self.service_cycles(i, batch);
        let start = arrival.max(self.busy_until[i]);
        let finish = start + svc;
        self.busy_until[i] = finish;
        self.served[i] += batch as u64;
        Routed {
            device: i,
            latency_cycles: finish - arrival,
            queued_cycles: start - arrival,
        }
    }

    /// Run a Poisson arrival experiment: `n` requests at `rate_fps`
    /// offered load; returns per-request latencies in ms.
    pub fn run_poisson(&mut self, n: usize, rate_fps: f64, seed: u64) -> Vec<f64> {
        self.reset();
        let mean_gap_cycles = CYCLES_PER_MS * 1e3 / rate_fps; // 200e6 / rate
        let mut rng = Rng::new(seed);
        let mut t = 0f64;
        let mut lats = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exp(mean_gap_cycles);
            let r = self.route(t as u64);
            lats.push(r.latency_cycles as f64 / CYCLES_PER_MS);
        }
        lats
    }

    /// Reset virtual time (new experiment).
    pub fn reset(&mut self) {
        self.busy_until.fill(0);
        self.served.fill(0);
    }

    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }
}

/// p-th percentile of a latency vector (ms).
pub fn percentile(lats: &[f64], p: f64) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    let mut v = lats.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() as f64 - 1.0) * p).round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};

    fn router(cards: usize, policy: Policy) -> Router {
        Router::new(cards, &TINY, AccelConfig::paper(), policy)
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = router(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0).device).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_card() {
        let mut r = router(2, Policy::LeastLoaded);
        let a = r.route(0);
        let b = r.route(0);
        assert_ne!(a.device, b.device);
        assert_eq!(b.queued_cycles, 0);
    }

    #[test]
    fn all_requests_served() {
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo] {
            let mut r = router(4, policy);
            let lats = r.run_poisson(200, 100.0, 7);
            assert_eq!(lats.len(), 200);
            assert_eq!(r.total_served(), 200);
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }

    #[test]
    fn more_cards_cut_tail_latency_under_overload() {
        // offered 80 FPS vs single-card capacity ~40 FPS: 1 card melts,
        // 4 cards keep the tail bounded
        let mut r1 = router(1, Policy::LeastLoaded);
        let mut r4 = router(4, Policy::LeastLoaded);
        let p99_1 = percentile(&r1.run_poisson(300, 80.0, 1), 0.99);
        let p99_4 = percentile(&r4.run_poisson(300, 80.0, 1), 0.99);
        assert!(
            p99_4 < p99_1 / 3.0,
            "1-card p99 {p99_1:.1} ms vs 4-card {p99_4:.1} ms"
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_under_bursts() {
        // identical arrivals; JSQ should not lose (allow small tie noise)
        let mut rr = router(4, Policy::RoundRobin);
        let mut ll = router(4, Policy::LeastLoaded);
        let p_rr = percentile(&rr.run_poisson(400, 140.0, 3), 0.99);
        let p_ll = percentile(&ll.run_poisson(400, 140.0, 3), 0.99);
        assert!(p_ll <= p_rr * 1.05, "rr {p_rr:.2} vs ll {p_ll:.2}");
    }

    #[test]
    fn heterogeneous_fleet_routes_over_trait_objects() {
        // a TINY card and a MICRO card behind one router: least-loaded
        // steers the bulk of the traffic to the much faster MICRO card
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0)),
            Box::new(SimEngine::new(1, &MICRO, AccelConfig::paper(), 0.0)),
        ];
        let mut r = Router::from_engines(engines, Policy::LeastLoaded);
        let lats = r.run_poisson(200, 100.0, 5);
        assert_eq!(lats.len(), 200);
        assert_eq!(r.total_served(), 200);
        assert!(r.served[1] > r.served[0], "served {:?}", r.served);
    }

    #[test]
    fn batched_route_amortises_service_time() {
        let mut r = router(1, Policy::RoundRobin);
        let solo = r.route(0).latency_cycles;
        r.reset();
        let batched = r.route_batch(0, 8).latency_cycles;
        // one 8-launch is far cheaper than eight sequential singles
        assert!(batched < 8 * solo, "batched {batched} vs 8x{solo}");
        assert_eq!(r.total_served(), 8);
    }

    #[test]
    fn percentile_helper() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }
}
