//! Scrape-able metrics endpoint for long-running serve processes.
//!
//! A minimal HTTP/1.1 responder over `std::net::TcpListener` (tokio /
//! hyper are not in the vendored registry): every request is answered
//! with one JSON document — the live serving [`Metrics`] (bounded-memory
//! reservoirs, per-SLO-class percentiles), per-card queue/class gauges,
//! a live shed counter (updated per drop, not only at the end of a run)
//! and the modelled pipeline-schedule summary
//! ([`crate::accel::pipeline::PipelineSchedule::summary_json`]) — built
//! with the crate's own [`Json`] serialiser.
//!
//! ```text
//! $ swin-fpga serve --sim swin-t --metrics-port 9090 &
//! $ curl localhost:9090/metrics.json
//! {"cards":{"0":{...}},"metrics":{"completed":64,...},"model":{...}}
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::util::json::Json;

use super::{Metrics, Response, Slo};

impl Metrics {
    /// JSON snapshot of the serving metrics (for the scrape endpoint).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert("shed".into(), Json::Num(self.shed as f64));
        o.insert("throughput_rps".into(), Json::Num(self.throughput()));
        o.insert("p50_ms".into(), Json::Num(self.percentile_ms(0.50)));
        o.insert("p95_ms".into(), Json::Num(self.percentile_ms(0.95)));
        o.insert("p99_ms".into(), Json::Num(self.percentile_ms(0.99)));
        o.insert("occupancy_mean".into(), Json::Num(self.occupancy_mean()));
        o.insert(
            "queue_depth_max".into(),
            Json::Num(self.queue_depth_max() as f64),
        );
        o.insert("wall_s".into(), Json::Num(self.wall.as_secs_f64()));
        let mut faults = BTreeMap::new();
        faults.insert("retries".into(), Json::Num(self.retries as f64));
        faults.insert("redispatches".into(), Json::Num(self.redispatches as f64));
        faults.insert("crash_losses".into(), Json::Num(self.crash_losses as f64));
        faults.insert("lost".into(), Json::Num(self.lost as f64));
        for (name, &n) in ["cards_up", "cards_degraded", "cards_draining", "cards_down"]
            .iter()
            .zip(&self.cards_by_health)
        {
            faults.insert((*name).into(), Json::Num(n as f64));
        }
        o.insert("faults".into(), Json::Obj(faults));
        let mut mix = BTreeMap::new();
        for (size, count) in &self.batches {
            mix.insert(size.to_string(), Json::Num(*count as f64));
        }
        o.insert("batch_mix".into(), Json::Obj(mix));
        let mut classes = BTreeMap::new();
        for class in Slo::ALL {
            let mut c = BTreeMap::new();
            c.insert(
                "completed".into(),
                Json::Num(self.class_completed[class.idx()] as f64),
            );
            c.insert(
                "p50_ms".into(),
                Json::Num(self.class_percentile_ms(class, 0.50)),
            );
            c.insert(
                "p99_ms".into(),
                Json::Num(self.class_percentile_ms(class, 0.99)),
            );
            classes.insert(class.name().into(), Json::Obj(c));
        }
        o.insert("classes".into(), Json::Obj(classes));
        Json::Obj(o)
    }
}

/// Live per-card gauges (updated on every recorded response).
#[derive(Debug, Default, Clone, Copy)]
struct CardGauge {
    /// Dispatch-time queue depth of the most recent launch.
    queue_depth: usize,
    /// Exact peak dispatch-time queue depth.
    queue_depth_peak: usize,
    served: u64,
    /// Served per class, indexed by [`Slo::idx`].
    class_served: [u64; 2],
}

impl CardGauge {
    fn to_json(self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("queue_depth".into(), Json::Num(self.queue_depth as f64));
        o.insert(
            "queue_depth_peak".into(),
            Json::Num(self.queue_depth_peak as f64),
        );
        o.insert("served".into(), Json::Num(self.served as f64));
        for class in Slo::ALL {
            o.insert(
                format!("served_{}", class.name()),
                Json::Num(self.class_served[class.idx()] as f64),
            );
        }
        Json::Obj(o)
    }
}

/// Shared state between the serving driver and the scrape endpoint:
/// live metrics plus the static model summary.
pub struct MetricsHub {
    metrics: Mutex<Metrics>,
    /// Live shed counter: incremented per dropped request so a mid-run
    /// scrape sees backpressure as it happens (`Metrics::shed` is only
    /// reconciled at [`MetricsHub::finish`]).
    shed: AtomicU64,
    /// Per-card queue/class gauges, keyed by `Response::card`.
    cards: Mutex<BTreeMap<usize, CardGauge>>,
    /// Modelled schedule summary (static per serve process).
    model: Json,
    /// Hub creation time: mid-run scrapes report elapsed wall time (the
    /// driver overwrites `Metrics::wall` with the exact figure at the
    /// end of the run).
    started: std::time::Instant,
}

impl MetricsHub {
    pub fn new(model: Json) -> Arc<MetricsHub> {
        Arc::new(MetricsHub {
            metrics: Mutex::new(Metrics::default()),
            shed: AtomicU64::new(0),
            cards: Mutex::new(BTreeMap::new()),
            model,
            started: std::time::Instant::now(),
        })
    }

    /// Record one completed response (called by the serving driver).
    pub fn record(&self, resp: &Response) {
        self.metrics.lock().unwrap().record(resp);
        let mut cards = self.cards.lock().unwrap();
        let g = cards.entry(resp.card).or_default();
        g.queue_depth = resp.queue_depth;
        g.queue_depth_peak = g.queue_depth_peak.max(resp.queue_depth);
        g.served += 1;
        g.class_served[resp.class.idx()] += 1;
    }

    /// Count one shed request — live, visible to the next scrape.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed so far (live counter).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Reconcile sheds / wall time in one shot at the end of a run.
    pub fn finish(&self, shed: u64, wall: Duration) {
        let mut m = self.metrics.lock().unwrap();
        m.shed = shed;
        m.wall = wall;
        self.shed.store(shed, Ordering::Relaxed);
    }

    /// Copy out the current metrics (shed reflects the live counter).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.shed = m.shed.max(self.shed.load(Ordering::Relaxed));
        m
    }

    /// The scrape document: `{"cards": ..., "metrics": ..., "model":
    /// ...}`. Mid-run (before [`MetricsHub::finish`]) the wall clock is
    /// the time since hub creation, so `throughput_rps` stays meaningful
    /// while scraping a live run.
    pub fn to_json(&self) -> Json {
        let mut m = self.metrics();
        if m.wall == Duration::ZERO {
            m.wall = self.started.elapsed();
        }
        let mut o = BTreeMap::new();
        o.insert("metrics".into(), m.to_json());
        let cards = self.cards.lock().unwrap();
        let mut cj = BTreeMap::new();
        for (id, g) in cards.iter() {
            cj.insert(id.to_string(), g.to_json());
        }
        o.insert("cards".into(), Json::Obj(cj));
        o.insert("model".into(), self.model.clone());
        Json::Obj(o)
    }
}

/// The endpoint: one listener thread answering every HTTP request with
/// the hub's JSON snapshot.
pub struct ScrapeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ScrapeServer {
    /// Bind and start serving. Use port 0 for an ephemeral port (tests);
    /// the bound address is reported by [`ScrapeServer::addr`].
    pub fn bind(addr: &str, hub: Arc<MetricsHub>) -> Result<ScrapeServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = thread::Builder::new()
            .name("metrics-scrape".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = stream {
                        let _ = answer(stream, &hub);
                    }
                }
            })?;
        Ok(ScrapeServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and release the port. Never blocks the
    /// caller indefinitely: if the listener cannot be woken it is
    /// detached instead of joined (it parks in `accept` holding only the
    /// socket and exits with the process).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // wake the blocking accept with a throwaway connection; a
        // wildcard bind address (0.0.0.0) is not connectable everywhere,
        // so rewrite it to loopback
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let woke = TcpStream::connect_timeout(&wake, Duration::from_millis(500)).is_ok();
        if let Some(h) = self.handle.take() {
            if woke {
                let _ = h.join();
            }
        }
    }
}

fn answer(mut stream: TcpStream, hub: &MetricsHub) -> std::io::Result<()> {
    // best-effort drain of the request head; the endpoint answers every
    // path identically, so the content (even an empty read) is irrelevant
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf).unwrap_or(0);
    let _request_head = &buf[..n];
    let body = hub.to_json().to_string();
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead as _;

    fn resp(id: u64, batch: usize, occ: usize, depth: usize, ms: u64, class: Slo, card: usize) -> Response {
        Response {
            id,
            logits: vec![],
            latency: Duration::from_millis(ms),
            batch,
            occupancy: occ,
            queue_depth: depth,
            class,
            card,
        }
    }

    fn get(addr: SocketAddr) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics.json HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut reader = std::io::BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line}");
        // skip headers
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h == "\r\n" {
                break;
            }
        }
        let mut body = String::new();
        reader.read_to_string(&mut body).unwrap();
        Json::parse(&body).expect("valid json body")
    }

    #[test]
    fn endpoint_serves_metrics_and_model_summary() {
        use crate::accel::pipeline::PipelineSchedule;
        use crate::accel::AccelConfig;
        use crate::model::config::MICRO;

        let model = PipelineSchedule::for_variant(&MICRO, AccelConfig::paper()).summary_json();
        let hub = MetricsHub::new(model);
        hub.record(&resp(0, 4, 3, 5, 3, Slo::Interactive, 0));
        hub.finish(2, Duration::from_secs(1));

        let srv = ScrapeServer::bind("127.0.0.1:0", hub.clone()).unwrap();
        let j = get(srv.addr());
        let m = j.get("metrics").unwrap();
        assert_eq!(m.get("completed").unwrap().as_usize(), Some(1));
        assert_eq!(m.get("shed").unwrap().as_usize(), Some(2));
        assert_eq!(m.get("batch_mix").unwrap().get("4").unwrap().as_usize(), Some(1));
        let model = j.get("model").unwrap();
        assert_eq!(model.get("variant").unwrap().as_str(), Some("swin-micro"));
        assert!(model.get("launch_cycles").unwrap().get("8").is_some());
        // a second scrape sees updated state
        hub.record(&resp(1, 1, 1, 1, 4, Slo::Batch, 0));
        let j2 = get(srv.addr());
        assert_eq!(
            j2.get("metrics").unwrap().get("completed").unwrap().as_usize(),
            Some(2)
        );
        srv.shutdown();
    }

    #[test]
    fn metrics_to_json_shape() {
        let mut m = Metrics::default();
        m.record(&resp(0, 8, 8, 9, 2, Slo::Interactive, 0));
        m.wall = Duration::from_secs(2);
        m.retries = 3;
        m.crash_losses = 2;
        m.cards_by_health = [3, 0, 0, 1];
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let f = j.get("faults").unwrap();
        assert_eq!(f.get("retries").unwrap().as_usize(), Some(3));
        assert_eq!(f.get("crash_losses").unwrap().as_usize(), Some(2));
        assert_eq!(f.get("redispatches").unwrap().as_usize(), Some(0));
        assert_eq!(f.get("lost").unwrap().as_usize(), Some(0));
        assert_eq!(f.get("cards_up").unwrap().as_usize(), Some(3));
        assert_eq!(f.get("cards_down").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(1));
        assert!(j.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!((j.get("occupancy_mean").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        let classes = j.get("classes").unwrap();
        assert_eq!(
            classes.get("interactive").unwrap().get("completed").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            classes.get("batch").unwrap().get("completed").unwrap().as_usize(),
            Some(0)
        );
    }

    #[test]
    fn shed_is_visible_mid_run_and_cards_gauge_classes() {
        let hub = MetricsHub::new(Json::Null);
        // a scrape between record_shed calls must see the live count —
        // the old hub only learned about sheds at finish()
        hub.record_shed();
        hub.record_shed();
        hub.record_shed();
        let j = Json::parse(&hub.to_json().to_string()).unwrap();
        assert_eq!(
            j.get("metrics").unwrap().get("shed").unwrap().as_usize(),
            Some(3)
        );
        // per-card gauges split served work by card and class
        hub.record(&resp(0, 8, 8, 11, 2, Slo::Interactive, 0));
        hub.record(&resp(1, 8, 8, 4, 2, Slo::Batch, 1));
        hub.record(&resp(2, 4, 4, 2, 2, Slo::Batch, 1));
        let j = Json::parse(&hub.to_json().to_string()).unwrap();
        let cards = j.get("cards").unwrap();
        let c0 = cards.get("0").unwrap();
        let c1 = cards.get("1").unwrap();
        assert_eq!(c0.get("served").unwrap().as_usize(), Some(1));
        assert_eq!(c0.get("queue_depth_peak").unwrap().as_usize(), Some(11));
        assert_eq!(c1.get("served").unwrap().as_usize(), Some(2));
        assert_eq!(c1.get("served_batch").unwrap().as_usize(), Some(2));
        assert_eq!(c1.get("served_interactive").unwrap().as_usize(), Some(0));
        assert_eq!(c1.get("queue_depth").unwrap().as_usize(), Some(2));
        // finish reconciles the authoritative totals
        hub.finish(5, Duration::from_secs(2));
        assert_eq!(hub.metrics().shed, 5);
        assert_eq!(hub.shed_count(), 5);
    }
}
