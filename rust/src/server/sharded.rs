//! Pipeline-parallel serving: one [`Engine`] over a sharded model.
//!
//! A [`ShardedEngine`] fronts an N-card pipeline group
//! ([`crate::accel::shard::ShardedSchedule`]) behind the same
//! batched-inference surface every other backend uses, so the continuous
//! batcher and the fleet router treat "Swin-L/384 across two cards" like
//! any other card:
//!
//! * **cold** ([`Engine::service_estimate`]) — the end-to-end pipeline
//!   latency of one launch: the sum of shard spans plus inter-card link
//!   transfers, as placed on the shared timeline;
//! * **warm** ([`Engine::steady_estimate`]) — the steady-state
//!   per-launch increment of a back-to-back stream: the *slowest
//!   shard's* warm rate (or the slowest link), which is what a queued
//!   launch actually costs once the pipeline is full;
//! * **energy** ([`Engine::launch_energy_uj`]) — per-launch µJ summed
//!   over the group: each card books its own shard's busy cycles into
//!   its own cold span, or into the common steady increment when warm
//!   (all N cards stay powered for the whole increment, so a poorly
//!   balanced group's warm launches can cost *more* joules than cold
//!   ones — the pipeline bubbles burn static power).
//!
//! Both read a shared [`ShardCostTable`] (`Arc`, memoized per bucket),
//! mirroring the single-card `SimEngine`/`CostTable` hot-path contract.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::accel::pipeline::Resource;
use crate::accel::power::{self, SpanBusy};
use crate::accel::shard::ShardCostTable;
use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;

use super::engine::{sim_logits, BatchOutput, Engine, BUCKET_SIZES};

/// Simulated pipeline group: a sharded schedule served as one engine.
pub struct ShardedEngine {
    id: usize,
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    sizes: Vec<usize>,
    img_len: usize,
    /// Shared cold/warm table of the sharded pipeline (one `Arc` per
    /// variant × config in a fleet; see [`ShardedEngine::with_table`]).
    table: Arc<ShardCostTable>,
    /// Virtual busy horizon of the pipeline group, in cycles (the group
    /// admits a new launch when its *entry* shard frees; the horizon
    /// advances by the steady increment once the pipeline is full).
    pub busy_until: u64,
    /// Images served (bookkeeping, mirrors `VirtualDevice::served`).
    pub served: u64,
    time_scale: f64,
}

impl ShardedEngine {
    /// Partition `variant` for XCZU19EG cards, lower every shard and
    /// memoize the serving buckets.
    pub fn new(
        id: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
    ) -> Self {
        let table = Arc::new(ShardCostTable::for_variant(
            variant,
            cfg,
            &BUCKET_SIZES,
        ));
        Self::with_table(id, variant, table, time_scale)
    }

    /// Build a pipeline group over an already-built shared cost table
    /// (fleet constructors lower the sharded schedule once per variant).
    pub fn with_table(
        id: usize,
        variant: &'static SwinVariant,
        table: Arc<ShardCostTable>,
        time_scale: f64,
    ) -> Self {
        ShardedEngine {
            id,
            variant,
            cfg: table.schedule().cfg.clone(),
            sizes: BUCKET_SIZES.to_vec(),
            img_len: variant.img_size * variant.img_size * variant.in_chans,
            table,
            busy_until: 0,
            served: 0,
            time_scale,
        }
    }

    /// The shared cost table this engine prices launches from.
    pub fn cost_table(&self) -> &Arc<ShardCostTable> {
        &self.table
    }

    /// Cards in the pipeline group.
    pub fn cards(&self) -> usize {
        self.table.schedule().cards()
    }

    /// Cold end-to-end pipeline latency of one batch-`batch` launch.
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.table.cold_cycles(batch)
    }

    /// Warm steady-state per-launch increment (slowest-shard rate).
    pub fn steady_launch_cycles(&self, batch: usize) -> u64 {
        self.table.warm_cycles(batch)
    }

    fn launch_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.launch_cycles(batch)) / 1e3)
    }

    fn steady_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.steady_launch_cycles(batch)) / 1e3)
    }

    /// Energy of one bucket-sized launch in µJ, summed over the pipeline
    /// group: every card books its own shard's busy cycles into its own
    /// active span — cold: that shard's launch span; warm: the common
    /// steady increment every card advances by once the pipeline is full.
    /// Each card's fabric (and so its static + infrastructure draw) is
    /// modelled as a full card of the group's variant; per-shard buffer
    /// plans differ modestly, a documented approximation.
    fn energy_uj_one(&self, batch: usize, warm: bool) -> u64 {
        let s = self.table.schedule();
        s.shards
            .iter()
            .map(|sh| {
                let busy = SpanBusy {
                    mmu: sh.busy_batched(Resource::Mmu, batch),
                    scu: sh.busy_batched(Resource::Scu, batch),
                    gcu: sh.busy_batched(Resource::Gcu, batch),
                    mru: sh.busy_batched(Resource::Mru, batch),
                };
                let span = if warm {
                    self.table.warm_cycles(batch)
                } else {
                    sh.launch_cycles(batch)
                };
                power::launch_energy_uj(self.variant, &self.cfg, busy, span)
            })
            .sum()
    }
}

impl Engine for ShardedEngine {
    fn name(&self) -> String {
        format!("shard:{}x{}#{}", self.variant.name, self.cards(), self.id)
    }

    fn card_id(&self) -> usize {
        self.id
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.variant.num_classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.launch_duration(b))
    }

    fn steady_estimate(&self, batch: usize) -> Duration {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.steady_duration(b))
    }

    fn launch_energy_uj(&self, batch: usize) -> u64 {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .map(|b| self.energy_uj_one(b, false))
            .sum()
    }

    fn steady_energy_uj(&self, batch: usize) -> u64 {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .map(|b| self.energy_uj_one(b, true))
            .sum()
    }

    fn wakeup_cycles(&self) -> u64 {
        // waking a gated group gates the *entry* card's first window;
        // downstream cards refill behind upstream compute, off the
        // critical path (the same reason only unit 0 gates on inputs)
        self.table
            .schedule()
            .shards
            .first()
            .map_or(0, |sh| sh.wakeup_fill_cycles())
    }

    fn idle_power_uw(&self) -> u64 {
        // every card in the group idles (and every card can be gated)
        self.cards() as u64 * power::idle_power_uw(self.variant, &self.cfg)
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        anyhow::ensure!(
            self.sizes.contains(&batch),
            "unsupported batch {batch} (buckets {:?})",
            self.sizes
        );
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let cycles = self.launch_cycles(batch);
        self.busy_until += cycles;
        self.served += batch as u64;
        let compute = self.launch_duration(batch);
        if self.time_scale > 0.0 {
            std::thread::sleep(compute.mul_f64(self.time_scale));
        }
        let classes = self.variant.num_classes;
        let mut logits = Vec::with_capacity(batch * classes);
        for img in images.chunks_exact(self.img_len) {
            logits.extend(sim_logits(img, classes));
        }
        Ok(BatchOutput { logits, compute })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::shard::ShardedSchedule;
    use crate::model::config::{BASE_384, LARGE_384, MICRO};

    #[test]
    fn sharded_engine_prices_from_the_sharded_schedule() {
        let e = ShardedEngine::new(0, &BASE_384, AccelConfig::paper(), 0.0);
        assert_eq!(e.cards(), 2);
        assert!(e.name().starts_with("shard:swin-b-384x2#"));
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        for b in BUCKET_SIZES {
            assert_eq!(e.launch_cycles(b), s.launch_cycles(b), "b={b}");
            assert_eq!(e.steady_launch_cycles(b), s.steady_launch_cycles(b));
            // warm (slowest-shard rate) strictly below cold (sum of
            // spans + links) — the pipeline-parallel gain
            assert!(e.steady_estimate(b) < e.service_estimate(b), "b={b}");
        }
        // above the largest bucket: the greedy decomposition, summed
        assert_eq!(
            e.service_estimate(16),
            e.service_estimate(8) + e.service_estimate(8)
        );
    }

    #[test]
    fn single_shard_group_matches_the_flat_sim_engine() {
        use super::super::engine::SimEngine;
        let sharded = ShardedEngine::new(0, &MICRO, AccelConfig::paper(), 0.0);
        let flat = SimEngine::new(0, &MICRO, AccelConfig::paper(), 0.0);
        assert_eq!(sharded.cards(), 1);
        for b in BUCKET_SIZES {
            assert_eq!(sharded.service_estimate(b), flat.service_estimate(b));
            assert_eq!(sharded.steady_estimate(b), flat.steady_estimate(b));
            // a one-card group is the flat card, energy included
            assert_eq!(sharded.launch_energy_uj(b), flat.launch_energy_uj(b));
            assert_eq!(sharded.steady_energy_uj(b), flat.steady_energy_uj(b));
        }
        assert_eq!(sharded.wakeup_cycles(), flat.wakeup_cycles());
        assert_eq!(sharded.idle_power_uw(), flat.idle_power_uw());
    }

    #[test]
    fn group_energy_sums_every_cards_span() {
        let e = ShardedEngine::new(0, &BASE_384, AccelConfig::paper(), 0.0);
        assert_eq!(e.cards(), 2);
        let s = e.cost_table().schedule();
        for b in BUCKET_SIZES {
            // independent recompute: each card's shard busy over its own
            // cold span, summed (the engine must not price the group as
            // one card or double-book the static draw)
            let expect: u64 = s
                .shards
                .iter()
                .map(|sh| {
                    let busy = SpanBusy {
                        mmu: sh.busy_batched(Resource::Mmu, b),
                        scu: sh.busy_batched(Resource::Scu, b),
                        gcu: sh.busy_batched(Resource::Gcu, b),
                        mru: sh.busy_batched(Resource::Mru, b),
                    };
                    power::launch_energy_uj(&BASE_384, &AccelConfig::paper(), busy, sh.launch_cycles(b))
                })
                .sum();
            assert_eq!(e.launch_energy_uj(b), expect, "b={b}");
            assert!(e.steady_energy_uj(b) > 0, "b={b}");
        }
        // decomposition above the largest bucket, as for time
        assert_eq!(e.launch_energy_uj(16), 2 * e.launch_energy_uj(8));
        // waking the group gates on the entry card's window only
        assert_eq!(e.wakeup_cycles(), s.shards[0].wakeup_fill_cycles());
        assert!(e.wakeup_cycles() > 0);
        // both cards idle (and can be gated)
        assert_eq!(
            e.idle_power_uw(),
            2 * power::idle_power_uw(&BASE_384, &AccelConfig::paper())
        );
    }

    #[test]
    fn run_batch_serves_and_advances_the_horizon() {
        let mut e = ShardedEngine::new(3, &LARGE_384, AccelConfig::paper(), 0.0);
        assert_eq!(e.card_id(), 3);
        let img_len = e.image_len();
        let images = vec![0.5f32; 2 * img_len];
        let out = e.run_batch(2, &images).unwrap();
        assert_eq!(out.logits.len(), 2 * e.num_classes());
        assert_eq!(e.served, 2);
        assert_eq!(e.busy_until, e.launch_cycles(2));
        assert!(e.run_batch(3, &images).is_err());
        // same image, same logits as any other sim backend
        let solo = sim_logits(&images[..img_len], e.num_classes());
        assert_eq!(&out.logits[..e.num_classes()], &solo[..]);
    }
}
