//! Pipeline-parallel serving: one [`Engine`] over a sharded model.
//!
//! A [`ShardedEngine`] fronts an N-card pipeline group
//! ([`crate::accel::shard::ShardedSchedule`]) behind the same
//! batched-inference surface every other backend uses, so the continuous
//! batcher and the fleet router treat "Swin-L/384 across two cards" like
//! any other card:
//!
//! * **cold** ([`Engine::service_estimate`]) — the end-to-end pipeline
//!   latency of one launch: the sum of shard spans plus inter-card link
//!   transfers, as placed on the shared timeline;
//! * **warm** ([`Engine::steady_estimate`]) — the steady-state
//!   per-launch increment of a back-to-back stream: the *slowest
//!   shard's* warm rate (or the slowest link), which is what a queued
//!   launch actually costs once the pipeline is full.
//!
//! Both read a shared [`ShardCostTable`] (`Arc`, memoized per bucket),
//! mirroring the single-card `SimEngine`/`CostTable` hot-path contract.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::accel::shard::ShardCostTable;
use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;

use super::engine::{sim_logits, BatchOutput, Engine, BUCKET_SIZES};

/// Simulated pipeline group: a sharded schedule served as one engine.
pub struct ShardedEngine {
    id: usize,
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    sizes: Vec<usize>,
    img_len: usize,
    /// Shared cold/warm table of the sharded pipeline (one `Arc` per
    /// variant × config in a fleet; see [`ShardedEngine::with_table`]).
    table: Arc<ShardCostTable>,
    /// Virtual busy horizon of the pipeline group, in cycles (the group
    /// admits a new launch when its *entry* shard frees; the horizon
    /// advances by the steady increment once the pipeline is full).
    pub busy_until: u64,
    /// Images served (bookkeeping, mirrors `VirtualDevice::served`).
    pub served: u64,
    time_scale: f64,
}

impl ShardedEngine {
    /// Partition `variant` for XCZU19EG cards, lower every shard and
    /// memoize the serving buckets.
    pub fn new(
        id: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
    ) -> Self {
        let table = Arc::new(ShardCostTable::for_variant(
            variant,
            cfg,
            &BUCKET_SIZES,
        ));
        Self::with_table(id, variant, table, time_scale)
    }

    /// Build a pipeline group over an already-built shared cost table
    /// (fleet constructors lower the sharded schedule once per variant).
    pub fn with_table(
        id: usize,
        variant: &'static SwinVariant,
        table: Arc<ShardCostTable>,
        time_scale: f64,
    ) -> Self {
        ShardedEngine {
            id,
            variant,
            cfg: table.schedule().cfg.clone(),
            sizes: BUCKET_SIZES.to_vec(),
            img_len: variant.img_size * variant.img_size * variant.in_chans,
            table,
            busy_until: 0,
            served: 0,
            time_scale,
        }
    }

    /// The shared cost table this engine prices launches from.
    pub fn cost_table(&self) -> &Arc<ShardCostTable> {
        &self.table
    }

    /// Cards in the pipeline group.
    pub fn cards(&self) -> usize {
        self.table.schedule().cards()
    }

    /// Cold end-to-end pipeline latency of one batch-`batch` launch.
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.table.cold_cycles(batch)
    }

    /// Warm steady-state per-launch increment (slowest-shard rate).
    pub fn steady_launch_cycles(&self, batch: usize) -> u64 {
        self.table.warm_cycles(batch)
    }

    fn launch_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.launch_cycles(batch)) / 1e3)
    }

    fn steady_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.steady_launch_cycles(batch)) / 1e3)
    }
}

impl Engine for ShardedEngine {
    fn name(&self) -> String {
        format!("shard:{}x{}#{}", self.variant.name, self.cards(), self.id)
    }

    fn card_id(&self) -> usize {
        self.id
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.variant.num_classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.launch_duration(b))
    }

    fn steady_estimate(&self, batch: usize) -> Duration {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.steady_duration(b))
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        anyhow::ensure!(
            self.sizes.contains(&batch),
            "unsupported batch {batch} (buckets {:?})",
            self.sizes
        );
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let cycles = self.launch_cycles(batch);
        self.busy_until += cycles;
        self.served += batch as u64;
        let compute = self.launch_duration(batch);
        if self.time_scale > 0.0 {
            std::thread::sleep(compute.mul_f64(self.time_scale));
        }
        let classes = self.variant.num_classes;
        let mut logits = Vec::with_capacity(batch * classes);
        for img in images.chunks_exact(self.img_len) {
            logits.extend(sim_logits(img, classes));
        }
        Ok(BatchOutput { logits, compute })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::shard::ShardedSchedule;
    use crate::model::config::{BASE_384, LARGE_384, MICRO};

    #[test]
    fn sharded_engine_prices_from_the_sharded_schedule() {
        let e = ShardedEngine::new(0, &BASE_384, AccelConfig::paper(), 0.0);
        assert_eq!(e.cards(), 2);
        assert!(e.name().starts_with("shard:swin-b-384x2#"));
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        for b in BUCKET_SIZES {
            assert_eq!(e.launch_cycles(b), s.launch_cycles(b), "b={b}");
            assert_eq!(e.steady_launch_cycles(b), s.steady_launch_cycles(b));
            // warm (slowest-shard rate) strictly below cold (sum of
            // spans + links) — the pipeline-parallel gain
            assert!(e.steady_estimate(b) < e.service_estimate(b), "b={b}");
        }
        // above the largest bucket: the greedy decomposition, summed
        assert_eq!(
            e.service_estimate(16),
            e.service_estimate(8) + e.service_estimate(8)
        );
    }

    #[test]
    fn single_shard_group_matches_the_flat_sim_engine() {
        use super::super::engine::SimEngine;
        let sharded = ShardedEngine::new(0, &MICRO, AccelConfig::paper(), 0.0);
        let flat = SimEngine::new(0, &MICRO, AccelConfig::paper(), 0.0);
        assert_eq!(sharded.cards(), 1);
        for b in BUCKET_SIZES {
            assert_eq!(sharded.service_estimate(b), flat.service_estimate(b));
            assert_eq!(sharded.steady_estimate(b), flat.steady_estimate(b));
        }
    }

    #[test]
    fn run_batch_serves_and_advances_the_horizon() {
        let mut e = ShardedEngine::new(3, &LARGE_384, AccelConfig::paper(), 0.0);
        assert_eq!(e.card_id(), 3);
        let img_len = e.image_len();
        let images = vec![0.5f32; 2 * img_len];
        let out = e.run_batch(2, &images).unwrap();
        assert_eq!(out.logits.len(), 2 * e.num_classes());
        assert_eq!(e.served, 2);
        assert_eq!(e.busy_until, e.launch_cycles(2));
        assert!(e.run_batch(3, &images).is_err());
        // same image, same logits as any other sim backend
        let solo = sim_logits(&images[..img_len], e.num_classes());
        assert_eq!(&out.logits[..e.num_classes()], &solo[..]);
    }
}
