//! Workload generation: arrival processes (plain and SLO-class-tagged)
//! and synthetic image streams for the serving experiments (the paper's
//! edge scenarios — autonomous driving / face recognition — imply steady
//! and bursty camera feeds, usually mixed with offline batch traffic).

use crate::util::prng::{CounterRng, Rng};

use super::batcher::Slo;
use super::router::CYCLES_PER_MS;

/// Arrival process shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Poisson at `rate` req/s.
    Poisson { rate: f64 },
    /// Fixed-interval camera feed at `fps`.
    Periodic { fps: f64 },
    /// Markov-modulated on/off bursts: Poisson `high` inside bursts of
    /// mean length `burst_s`, silent gaps of mean `gap_s`.
    Bursty { high: f64, burst_s: f64, gap_s: f64 },
}

/// Generate `n` arrival timestamps (seconds, ascending).
pub fn arrivals(kind: Arrival, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    match kind {
        Arrival::Poisson { rate } => {
            let mut t = 0.0;
            for _ in 0..n {
                t += rng.exp(1.0 / rate);
                out.push(t);
            }
        }
        Arrival::Periodic { fps } => {
            for i in 0..n {
                out.push((i + 1) as f64 / fps);
            }
        }
        Arrival::Bursty { high, burst_s, gap_s } => {
            let mut t = 0.0;
            let mut burst_end = rng.exp(burst_s);
            while out.len() < n {
                let gap = rng.exp(1.0 / high);
                t += gap;
                if t > burst_end {
                    t += rng.exp(gap_s); // silent period
                    burst_end = t + rng.exp(burst_s);
                }
                out.push(t);
            }
        }
    }
    out
}

/// One arrival of a class-tagged stream (seconds, ascending).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassedArrival {
    pub t: f64,
    pub class: Slo,
}

/// Tag an arrival process with SLO classes: each request is
/// [`Slo::Interactive`] with probability `interactive_share`
/// (deterministic per seed, independent of the arrival shape).
pub fn classed_arrivals(
    kind: Arrival,
    n: usize,
    interactive_share: f64,
    seed: u64,
) -> Vec<ClassedArrival> {
    let mut class_rng = Rng::new(seed ^ 0xC1A5_5E5);
    arrivals(kind, n, seed)
        .into_iter()
        .map(|t| ClassedArrival {
            t,
            class: if class_rng.f64() < interactive_share {
                Slo::Interactive
            } else {
                Slo::Batch
            },
        })
        .collect()
}

/// Class-tagged bursty arrivals whose *long-run mean* rate is
/// `frac × capacity_fps` — the Pareto-experiment workload (PR 9).
///
/// Bursts fire at twice the mean rate with equal burst/gap dwell
/// (duty ≈ 0.5), so a fleet provisioned for `capacity_fps` sees
/// transient overload *inside* bursts even when the long-run load sits
/// below capacity — exactly the regime where energy-aware routing has
/// room to trade idle draw against latency headroom.
pub fn bursty_at_fraction(
    frac: f64,
    capacity_fps: f64,
    n: usize,
    interactive_share: f64,
    seed: u64,
) -> Vec<ClassedArrival> {
    let mean = frac * capacity_fps;
    classed_arrivals(
        Arrival::Bursty { high: 2.0 * mean, burst_s: 0.25, gap_s: 0.25 },
        n,
        interactive_share,
        seed,
    )
}

/// Per-shard arrival substream for the sharded router's streaming
/// (billion-arrival) mode: an incremental, class-tagged generator whose
/// randomness comes from two splittable counter-based streams derived
/// from `(seed, shard)` — `stream(2·shard)` for inter-arrival gaps,
/// `stream(2·shard + 1)` for SLO-class tags. Because [`CounterRng`]
/// output is a pure function of `(key, counter)`, the substream replays
/// exactly for any thread count and any epoch chunking; shard
/// substreams are *independent* arrival processes (the fleet's offered
/// load is their superposition), not a partition of one stream.
///
/// Arrival shapes mirror [`arrivals`] draw-for-draw; timestamps are
/// emitted pre-converted to virtual cycles. (The fault layer's
/// [`crate::server::FaultPlan::random`] keys per-card event substreams
/// the same way — `(seed, card)` — so faulted generated-mode runs stay
/// bit-identical for any thread count.)
#[derive(Debug, Clone)]
pub struct ShardArrivalGen {
    kind: Arrival,
    n: usize,
    produced: usize,
    t: f64,
    burst_end: f64,
    interactive_share: f64,
    gap_rng: CounterRng,
    class_rng: CounterRng,
    pending: Option<(u64, Slo)>,
}

impl ShardArrivalGen {
    /// Substream `shard` of workload `seed`: `n` arrivals of `kind`,
    /// tagged [`Slo::Interactive`] with probability `interactive_share`.
    pub fn new(
        kind: Arrival,
        n: usize,
        interactive_share: f64,
        seed: u64,
        shard: u64,
    ) -> Self {
        let root = CounterRng::new(seed);
        let mut gap_rng = root.stream(2 * shard);
        let burst_end = match kind {
            Arrival::Bursty { burst_s, .. } => gap_rng.exp(burst_s),
            _ => 0.0,
        };
        ShardArrivalGen {
            kind,
            n,
            produced: 0,
            t: 0.0,
            burst_end,
            interactive_share,
            gap_rng,
            class_rng: root.stream(2 * shard + 1),
            pending: None,
        }
    }

    /// Pop the next arrival if it lands strictly before `t_end_cycles`
    /// (an epoch's end boundary); otherwise hold it as pending for a
    /// later epoch. Returns `(arrival_cycles, class)`.
    pub fn next_before(&mut self, t_end_cycles: u64) -> Option<(u64, Slo)> {
        if self.pending.is_none() {
            if self.produced >= self.n {
                return None;
            }
            match self.kind {
                Arrival::Poisson { rate } => {
                    self.t += self.gap_rng.exp(1.0 / rate);
                }
                Arrival::Periodic { fps } => {
                    self.t = (self.produced + 1) as f64 / fps;
                }
                Arrival::Bursty { high, burst_s, gap_s } => {
                    self.t += self.gap_rng.exp(1.0 / high);
                    if self.t > self.burst_end {
                        self.t += self.gap_rng.exp(gap_s); // silent period
                        self.burst_end = self.t + self.gap_rng.exp(burst_s);
                    }
                }
            }
            let cycles = (self.t * 1e3 * CYCLES_PER_MS) as u64;
            let class = if self.class_rng.f64() < self.interactive_share {
                Slo::Interactive
            } else {
                Slo::Batch
            };
            self.produced += 1;
            self.pending = Some((cycles, class));
        }
        match self.pending {
            Some((t, _)) if t < t_end_cycles => self.pending.take(),
            _ => None,
        }
    }

    /// True once all `n` arrivals have been handed out.
    pub fn done(&self) -> bool {
        self.produced >= self.n && self.pending.is_none()
    }

    /// Arrivals handed out so far (pending counts as produced).
    pub fn produced(&self) -> usize {
        self.produced - usize::from(self.pending.is_some())
    }
}

/// Merge an interactive stream and a batch stream into one ascending
/// class-tagged stream (the mixed-tenancy fleet scenario: a live camera
/// feed riding on top of offline batch traffic).
pub fn merge_classed(interactive: &[f64], batch: &[f64]) -> Vec<ClassedArrival> {
    let mut out = Vec::with_capacity(interactive.len() + batch.len());
    let (mut i, mut j) = (0, 0);
    while i < interactive.len() || j < batch.len() {
        let take_interactive = match (interactive.get(i), batch.get(j)) {
            (Some(&a), Some(&b)) => a <= b,
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_interactive {
            out.push(ClassedArrival { t: interactive[i], class: Slo::Interactive });
            i += 1;
        } else {
            out.push(ClassedArrival { t: batch[j], class: Slo::Batch });
            j += 1;
        }
    }
    out
}

/// Synthetic image stream: class-template images matching the Table II
/// dataset generator (template + amplitude + noise), so the served model
/// sees a realistic, classifiable distribution rather than white noise.
pub struct ImageStream {
    templates: Vec<Vec<f32>>,
    pixels: usize,
    rng: Rng,
}

impl ImageStream {
    pub fn new(num_classes: usize, pixels: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let templates = (0..num_classes)
            .map(|_| rng.normal_vec(pixels, 0.5))
            .collect();
        ImageStream {
            templates,
            pixels,
            rng,
        }
    }

    /// Next (class, image) sample.
    pub fn next_labeled(&mut self) -> (usize, Vec<f32>) {
        let k = self.rng.below(self.templates.len() as u64) as usize;
        let amp = 0.5 + self.rng.f64() as f32;
        let img: Vec<f32> = self.templates[k]
            .iter()
            .map(|&t| t * amp + 0.8 * self.rng.normal() as f32)
            .collect();
        (k, img)
    }

    pub fn next_image(&mut self) -> Vec<f32> {
        self.next_labeled().1
    }

    pub fn pixels(&self) -> usize {
        self.pixels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_honoured() {
        let a = arrivals(Arrival::Poisson { rate: 100.0 }, 2000, 1);
        let span = a.last().unwrap() - a[0];
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "rate={rate}");
    }

    #[test]
    fn arrivals_ascending() {
        for kind in [
            Arrival::Poisson { rate: 50.0 },
            Arrival::Periodic { fps: 30.0 },
            Arrival::Bursty { high: 200.0, burst_s: 0.1, gap_s: 0.2 },
        ] {
            let a = arrivals(kind, 500, 3);
            assert_eq!(a.len(), 500);
            for w in a.windows(2) {
                assert!(w[1] >= w[0]);
            }
        }
    }

    #[test]
    fn periodic_is_exact() {
        let a = arrivals(Arrival::Periodic { fps: 25.0 }, 50, 0);
        assert!((a[24] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let var = |xs: &[f64]| {
            let gaps: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64
        };
        let p = arrivals(Arrival::Poisson { rate: 100.0 }, 2000, 5);
        let b = arrivals(
            Arrival::Bursty { high: 200.0, burst_s: 0.05, gap_s: 0.1 },
            2000,
            5,
        );
        assert!(var(&b) > var(&p), "bursty {} vs poisson {}", var(&b), var(&p));
    }

    #[test]
    fn classed_arrivals_share_and_determinism() {
        let a = classed_arrivals(Arrival::Poisson { rate: 100.0 }, 2_000, 0.3, 5);
        let b = classed_arrivals(Arrival::Poisson { rate: 100.0 }, 2_000, 0.3, 5);
        assert_eq!(a, b, "same seed, same stream");
        // timestamps match the untagged generator exactly
        let plain = arrivals(Arrival::Poisson { rate: 100.0 }, 2_000, 5);
        assert!(a.iter().zip(&plain).all(|(c, &t)| c.t == t));
        let share = a.iter().filter(|c| c.class == Slo::Interactive).count() as f64 / 2_000.0;
        assert!((share - 0.3).abs() < 0.05, "share={share}");
        // degenerate shares are exact
        assert!(classed_arrivals(Arrival::Periodic { fps: 10.0 }, 50, 1.0, 1)
            .iter()
            .all(|c| c.class == Slo::Interactive));
        assert!(classed_arrivals(Arrival::Periodic { fps: 10.0 }, 50, 0.0, 1)
            .iter()
            .all(|c| c.class == Slo::Batch));
    }

    #[test]
    fn bursty_at_fraction_hits_the_target_mean_rate() {
        // long-run rate ≈ frac × capacity; in-burst rate is 2× the mean
        let a = bursty_at_fraction(0.7, 1000.0, 8_000, 0.5, 21);
        assert_eq!(a, bursty_at_fraction(0.7, 1000.0, 8_000, 0.5, 21));
        let span = a.last().unwrap().t - a[0].t;
        let rate = 8_000.0 / span;
        assert!(
            (rate - 700.0).abs() < 100.0,
            "mean offered rate {rate} should sit near 0.7 × 1000 fps"
        );
        // burstiness: gap variance well above a Poisson of the same mean
        let var = |xs: &[ClassedArrival]| {
            let gaps: Vec<f64> = xs.windows(2).map(|w| w[1].t - w[0].t).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64
        };
        let p = classed_arrivals(Arrival::Poisson { rate: 700.0 }, 8_000, 0.5, 21);
        assert!(var(&a) > var(&p), "bursty {} vs poisson {}", var(&a), var(&p));
    }

    #[test]
    fn merge_classed_interleaves_ascending() {
        let interactive = arrivals(Arrival::Periodic { fps: 30.0 }, 30, 0);
        let batch = arrivals(Arrival::Bursty { high: 300.0, burst_s: 0.05, gap_s: 0.1 }, 60, 2);
        let merged = merge_classed(&interactive, &batch);
        assert_eq!(merged.len(), 90);
        for w in merged.windows(2) {
            assert!(w[1].t >= w[0].t, "merge must stay ascending");
        }
        assert_eq!(
            merged.iter().filter(|c| c.class == Slo::Interactive).count(),
            30
        );
    }

    fn drain_gen(mut g: ShardArrivalGen, chunk_cycles: u64) -> Vec<(u64, Slo)> {
        let mut out = Vec::new();
        let mut end = chunk_cycles;
        while !g.done() {
            while let Some(a) = g.next_before(end) {
                out.push(a);
            }
            end = end.saturating_add(chunk_cycles);
        }
        out
    }

    #[test]
    fn shard_gen_replays_identically_under_any_epoch_chunking() {
        // The epoch boundary schedule must not affect the substream: a
        // counter-based draw depends only on (seed, shard, index).
        for kind in [
            Arrival::Poisson { rate: 400.0 },
            Arrival::Periodic { fps: 120.0 },
            Arrival::Bursty { high: 800.0, burst_s: 0.2, gap_s: 0.3 },
        ] {
            let mk = |shard| ShardArrivalGen::new(kind, 700, 0.5, 31, shard);
            let fine = drain_gen(mk(2), 10_000);
            let coarse = drain_gen(mk(2), 50_000_000);
            let one_shot = drain_gen(mk(2), u64::MAX);
            assert_eq!(fine, coarse, "{kind:?}: chunking changed the stream");
            assert_eq!(fine, one_shot, "{kind:?}: chunking changed the stream");
            for w in fine.windows(2) {
                assert!(w[1].0 >= w[0].0, "substream must ascend");
            }
            // distinct shards are distinct processes off the same seed
            assert_ne!(fine, drain_gen(mk(3), 10_000));
            assert_eq!(fine.len(), 700);
        }
    }

    #[test]
    fn shard_gen_share_and_rates_match_the_vec_generator() {
        let kind = Arrival::Poisson { rate: 500.0 };
        let stream = drain_gen(ShardArrivalGen::new(kind, 4_000, 0.3, 9, 0), u64::MAX);
        let share = stream.iter().filter(|a| a.1 == Slo::Interactive).count() as f64
            / stream.len() as f64;
        assert!((share - 0.3).abs() < 0.05, "share={share}");
        let span_ms = (stream.last().unwrap().0 - stream[0].0) as f64 / CYCLES_PER_MS;
        let rate = 4_000.0 / (span_ms / 1e3);
        assert!((rate - 500.0).abs() < 50.0, "rate={rate}");
        // done()/produced() bookkeeping
        let mut g = ShardArrivalGen::new(kind, 3, 1.0, 1, 0);
        assert!(!g.done());
        assert_eq!(g.produced(), 0);
        let _ = g.next_before(u64::MAX);
        assert_eq!(g.produced(), 1);
        while g.next_before(u64::MAX).is_some() {}
        assert!(g.done());
        assert_eq!(g.produced(), 3);
    }

    #[test]
    fn image_stream_deterministic_and_sized() {
        let mut a = ImageStream::new(10, 9408, 42);
        let mut b = ImageStream::new(10, 9408, 42);
        let (ka, ia) = a.next_labeled();
        let (kb, ib) = b.next_labeled();
        assert_eq!(ka, kb);
        assert_eq!(ia, ib);
        assert_eq!(ia.len(), 9408);
        assert_eq!(a.pixels(), 9408);
    }

    #[test]
    fn image_stream_classes_distinguishable() {
        // same class twice correlates more than different classes
        let mut s = ImageStream::new(2, 1024, 7);
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![vec![], vec![]];
        while by_class[0].len() < 3 || by_class[1].len() < 3 {
            let (k, img) = s.next_labeled();
            by_class[k].push(img);
        }
        let corr = |a: &[f32], b: &[f32]| -> f64 {
            let dot: f64 = a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum();
            let na: f64 = a.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt();
            let nb: f64 = b.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt();
            dot / (na * nb)
        };
        let same = corr(&by_class[0][0], &by_class[0][1]);
        let diff = corr(&by_class[0][0], &by_class[1][0]);
        assert!(same > diff, "same={same} diff={diff}");
    }
}
