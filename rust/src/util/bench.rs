//! Micro-benchmark harness (criterion is not in the vendored registry).
//!
//! Deliberately simple and deterministic: fixed warmup, fixed measurement
//! budget, reports mean / p50 / p99 / throughput. Each `rust/benches/*.rs`
//! binary uses this plus `report::Table` to print its paper table.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then measure for at least
/// `measure` (and at least 10 iterations), timing each call.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    let wend = Instant::now() + warmup;
    while Instant::now() < wend {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let mend = Instant::now() + measure;
    while Instant::now() < mend || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean: total / n as u32,
        p50: samples[n / 2],
        p99: samples[((n * 99) / 100).min(n - 1)],
        min: samples[0],
    }
}

/// Quick default: 200 ms warmup, 1 s measurement.
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(
        name,
        Duration::from_millis(200),
        Duration::from_secs(1),
        f,
    )
}

/// Black-box to stop the optimiser deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p99),
            self.iters
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = bench(
            "spin",
            Duration::from_millis(5),
            Duration::from_millis(30),
            || {
                black_box((0..1000u64).sum::<u64>());
            },
        );
        assert!(r.iters >= 10);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
    }

    #[test]
    fn formats_durations() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_duration(Duration::from_micros(15)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(15)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains(" s"));
    }
}
