//! Minimal JSON parser — enough for the artifact/weight manifests emitted
//! by `python/compile/aot.py` (objects, arrays, strings, numbers, bools,
//! null; no \u escapes beyond BMP pass-through).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    /// Compact serialisation (used for metrics endpoints and reports).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .b
                        .get(start..self.pos)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "format": "hlo-text", "data_frac": 8,
          "artifacts": {
            "kernel_mmu.hlo.txt": {"kind": "kernel", "rshift": 12,
              "inputs": [{"shape": [49, 96], "dtype": "i32"}]}
          },
          "flag": true, "nothing": null, "neg": -1.5e2
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        assert_eq!(j.get("data_frac").unwrap().as_usize().unwrap(), 8);
        let art = j.get("artifacts").unwrap().get("kernel_mmu.hlo.txt").unwrap();
        assert_eq!(art.get("rshift").unwrap().as_usize().unwrap(), 12);
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        assert_eq!(
            shape.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect::<Vec<_>>(),
            vec![49, 96]
        );
        assert_eq!(j.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
        assert_eq!(j.get("neg").unwrap().as_f64(), Some(-150.0));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse(r#"{"k": "héllo → 世界"}"#).unwrap();
        assert_eq!(j.get("k").unwrap().as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("trueX").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let doc = r#"{"a":[1,2,{"b":"x\ny"}],"c":true}"#;
        let j = Json::parse(doc).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
