//! Offline substrates: the image's vendored crate registry contains only
//! the xla-example closure (no serde_json / rand / criterion / proptest /
//! tokio / clap), so the small pieces of those we need are implemented
//! here and tested like any other module.

pub mod bench;
pub mod json;
pub mod prng;
pub mod stats;
