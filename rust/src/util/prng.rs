//! Deterministic PRNG (SplitMix64 + xoshiro256** + a splittable
//! counter-based generator) for tests, property tests and workload
//! generation — the vendored registry has no `rand`.

/// SplitMix64: seeds the main generator and is a fine generator itself
/// for non-crypto use.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free is overkill; modulo bias is
        // negligible for n << 2^64 in test workloads.
        self.next_u64() % n
    }

    /// Uniform i32 in [lo, hi).
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a vec with N(0, sigma) f32 values.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * sigma).collect()
    }

    /// Exponential with mean `mu` (for Poisson request arrivals).
    pub fn exp(&mut self, mu: f64) -> f64 {
        -mu * (1.0 - self.f64()).ln()
    }
}

/// SplitMix64's additive constant (2^64 / φ, odd).
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// The SplitMix64 finalizer as a pure function: a bijective avalanche
/// mix of one u64.
#[inline]
pub fn mix64(z: u64) -> u64 {
    let mut z = z;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Splittable **counter-based** generator: output `i` of a stream is a
/// pure function of `(key, i)` — `mix64(key + (i+1)·GOLDEN)`, i.e.
/// random-access SplitMix64 — so a stream replays exactly regardless of
/// which thread consumes it, from any starting counter, in any chunking.
/// `stream(s)` derives an independent child key from `(key, s)`; the
/// sharded router gives substream `s` of workload seed `seed` the key
/// `CounterRng::new(seed).stream(s)`, which makes every shard's
/// arrival/jitter stream a function of `(seed, shard)` alone. The fault
/// layer reuses the same construction: `FaultPlan::random` draws card
/// `c`'s events from `stream(c)`, so a plan's events are a pure
/// function of `(seed, card)` and survive shard splitting
/// (`FaultPlan::subplan`) bit-for-bit.
#[derive(Debug, Clone)]
pub struct CounterRng {
    key: u64,
    ctr: u64,
}

impl CounterRng {
    pub fn new(seed: u64) -> Self {
        Self { key: mix64(seed ^ 0x5EED_C0DE_D15E_A5E5), ctr: 0 }
    }

    /// Derive the key of child stream `s`: a pure function of
    /// `(self.key, s)`, independent of any generation done so far.
    pub fn stream(&self, s: u64) -> CounterRng {
        CounterRng {
            key: mix64(self.key ^ mix64(s.wrapping_mul(GOLDEN) ^ 0x0DD0_5EED)),
            ctr: 0,
        }
    }

    /// Random access: output `i` of this stream (does not advance).
    #[inline]
    pub fn nth(&self, i: u64) -> u64 {
        mix64(self.key.wrapping_add(i.wrapping_add(1).wrapping_mul(GOLDEN)))
    }

    pub fn next_u64(&mut self) -> u64 {
        let v = self.nth(self.ctr);
        self.ctr += 1;
        v
    }

    /// Uniform f64 in [0, 1) — same 53-bit construction as [`Rng::f64`].
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential with mean `mu` — same transform as [`Rng::exp`].
    pub fn exp(&mut self, mu: f64) -> f64 {
        -mu * (1.0 - self.f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..10_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range_i32(-5, 17);
            assert!((-5..17).contains(&v));
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.exp(2.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 2.0).abs() < 0.08, "mean={mean}");
    }

    #[test]
    fn counter_rng_is_random_access() {
        let mut seq = CounterRng::new(99).stream(3);
        let walked: Vec<u64> = (0..64).map(|_| seq.next_u64()).collect();
        let jumped: Vec<u64> =
            (0..64).map(|i| CounterRng::new(99).stream(3).nth(i)).collect();
        assert_eq!(walked, jumped);
    }

    #[test]
    fn counter_rng_streams_are_independent_of_consumption() {
        // Deriving stream(s) after consuming the parent must match a
        // fresh derivation: splitting is a pure function of (key, s).
        let mut parent = CounterRng::new(7);
        for _ in 0..17 {
            parent.next_u64();
        }
        let mut a = parent.stream(5);
        let mut b = CounterRng::new(7).stream(5);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = CounterRng::new(7).stream(6);
        assert_ne!(
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| c.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn counter_rng_uniform_and_exp_moments() {
        let mut r = CounterRng::new(21).stream(0);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
        let mut r = CounterRng::new(22).stream(1);
        let es: Vec<f64> = (0..20_000).map(|_| r.exp(2.0)).collect();
        let emean = es.iter().sum::<f64>() / es.len() as f64;
        assert!((emean - 2.0).abs() < 0.08, "mean={emean}");
    }
}
