//! Bounded-memory streaming statistics: a fixed-size uniform reservoir
//! (Vitter's Algorithm R) so long-running serve processes can report
//! percentiles without per-request memory growth.
//!
//! Below `cap` samples the reservoir is exact; past it every sample seen
//! so far has equal probability `cap / seen` of being retained, so
//! percentile estimates stay unbiased while memory stays O(cap). The
//! replacement PRNG is seeded deterministically, so metrics snapshots are
//! reproducible run-to-run for identical inputs.

use super::prng::Rng;

/// Default reservoir capacity: plenty for stable p99 estimates while
/// bounding a serve process to a few tens of KiB per tracked series.
pub const RESERVOIR_CAP: usize = 4096;

/// Fixed-size uniform sample over an unbounded stream of `f64` values.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    vals: Vec<f64>,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::new(RESERVOIR_CAP)
    }
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            vals: Vec::new(),
            rng: Rng::new(0x5EED_CAFE),
        }
    }

    /// Offer one sample (Algorithm R).
    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.vals.len() < self.cap {
            self.vals.push(v);
        } else {
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.vals[j] = v;
            }
        }
    }

    /// Samples currently held (≤ cap).
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Total samples offered over the stream's lifetime.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Retained samples (unordered).
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// p-th percentile (nearest rank) of the retained sample; exact when
    /// the stream never exceeded `cap`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        let mut v = self.vals.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }

    /// Mean of the retained sample.
    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().sum::<f64>() / self.vals.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_below_capacity() {
        let mut r = Reservoir::new(16);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push(v);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.seen(), 4);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 4.0);
        assert!((r.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn memory_stays_bounded() {
        let mut r = Reservoir::new(64);
        for i in 0..100_000u64 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 64);
        assert_eq!(r.seen(), 100_000);
    }

    #[test]
    fn sampled_percentiles_track_the_stream() {
        // uniform ramp 0..100k through a 4k reservoir: quartiles land
        // within a few percent of truth (deterministic seed, exact run)
        let mut r = Reservoir::default();
        let n = 100_000u64;
        for i in 0..n {
            r.push(i as f64);
        }
        for (p, truth) in [(0.25, 25_000.0), (0.5, 50_000.0), (0.95, 95_000.0)] {
            let got = r.percentile(p);
            assert!(
                (got - truth).abs() < 0.05 * n as f64,
                "p{p}: got {got}, want ~{truth}"
            );
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut r = Reservoir::new(32);
            for i in 0..10_000u64 {
                r.push((i % 977) as f64);
            }
            r.values().to_vec()
        };
        assert_eq!(run(), run());
    }
}
