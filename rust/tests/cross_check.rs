//! The cross-check contract (DESIGN.md §2): the Rust functional models
//! and the AOT-compiled Pallas kernels must be **bit-identical**, and the
//! whole-model functional simulator must match the AOT fixed-point Swin
//! artifact exactly.
//!
//! Requires `artifacts/` (produced by `python/compile/aot.py`) **and** a
//! real PJRT runtime. When either is absent the tests skip with a notice
//! instead of failing — the environment simply cannot execute HLO; the
//! artifact-free invariants live in `sim_invariants`/`serving_batcher`.

use std::path::{Path, PathBuf};

use swin_fpga::accel::functional::FunctionalModel;
use swin_fpga::accel::mmu::Mmu;
use swin_fpga::accel::tiling::IntMat;
use swin_fpga::accel::AccelConfig;
use swin_fpga::approx::{gelu, softmax};
use swin_fpga::fixed::WEIGHT_FRAC;
use swin_fpga::model::config::MICRO;
use swin_fpga::model::weights::WeightStore;
use swin_fpga::runtime::{Runtime, Tensor};
use swin_fpga::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing (run the AOT pipeline first)");
        None
    }
}

// PJRT handles are Rc-based (!Send/!Sync): each test owns its Runtime.
// Returns None (skip) when the PJRT backend is unavailable (xla stub).
fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    match Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn mmu_kernel_bit_exact() {
    let Some(rt) = runtime() else { return };
    let eng = rt.engine("kernel_mmu.hlo.txt").unwrap();
    let (ra, ka) = (eng.info.inputs[0].shape[0], eng.info.inputs[0].shape[1]);
    let (kb, nb) = (eng.info.inputs[1].shape[0], eng.info.inputs[1].shape[1]);
    assert_eq!(ka, kb);
    let mut rng = Rng::new(101);
    for round in 0..3 {
        let a: Vec<i32> = (0..ra * ka).map(|_| rng.range_i32(-3000, 3000)).collect();
        let b: Vec<i32> = (0..kb * nb).map(|_| rng.range_i32(-3000, 3000)).collect();
        let out = eng
            .run(&[Tensor::I32(a.clone()), Tensor::I32(b.clone())])
            .unwrap();
        let want = Mmu::new(AccelConfig::paper()).gemm(
            &IntMat::from_vec(ra, ka, a),
            &IntMat::from_vec(kb, nb, b),
            WEIGHT_FRAC,
        );
        assert_eq!(out.as_i32().unwrap(), want.data.as_slice(), "round {round}");
    }
}

#[test]
fn softmax_kernel_bit_exact() {
    let Some(rt) = runtime() else { return };
    let eng = rt.engine("kernel_softmax.hlo.txt").unwrap();
    let (rows, width) = (eng.info.inputs[0].shape[0], eng.info.inputs[0].shape[1]);
    let n_valid = 49usize;
    let neg_pad = -(1 << 14);
    let mut rng = Rng::new(202);
    // build rows with the same NEG_PAD sentinel the kernel applies
    let mut x = vec![0i32; rows * width];
    for r in 0..rows {
        for c in 0..width {
            x[r * width + c] = if c < n_valid {
                rng.range_i32(-2000, 2000)
            } else {
                12345 // kernel masks these internally; any junk value
            };
        }
    }
    let out = eng.run(&[Tensor::I32(x.clone())]).unwrap();
    // rust golden: apply the mask, then SCU over the padded width
    let mut masked = x;
    for r in 0..rows {
        for c in n_valid..width {
            masked[r * width + c] = neg_pad;
        }
    }
    let want = softmax::softmax_rows(&masked, width);
    assert_eq!(out.as_i32().unwrap(), want.as_slice());
}

#[test]
fn gelu_kernels_bit_exact() {
    let Some(rt) = runtime() else { return };
    for (name, corrected) in [
        ("kernel_gelu.hlo.txt", false),
        ("kernel_gelu_corrected.hlo.txt", true),
    ] {
        let eng = rt.engine(name).unwrap();
        let n = eng.info.inputs[0].numel();
        let mut rng = Rng::new(303);
        let x: Vec<i32> = (0..n).map(|_| rng.range_i32(-2100, 2100)).collect();
        let out = eng.run(&[Tensor::I32(x.clone())]).unwrap();
        let want = gelu::gelu_slice(&x, corrected);
        assert_eq!(out.as_i32().unwrap(), want.as_slice(), "{name}");
    }
}

fn load_weights(dir: &Path) -> WeightStore {
    WeightStore::load(
        &dir.join("weights_micro.bin"),
        &dir.join("weights_micro_manifest.json"),
    )
    .expect("weight store")
}

#[test]
fn full_model_functional_matches_aot_fixed_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let eng = rt.engine("swin_micro_fixed_b1.hlo.txt").unwrap();
    let ws = load_weights(&dir);
    let model = FunctionalModel::new(&MICRO, &ws, AccelConfig::paper());

    let mut rng = Rng::new(404);
    for round in 0..2 {
        let img: Vec<f32> = (0..56 * 56 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let aot = eng.run(&[Tensor::F32(img.clone())]).unwrap();
        let ours = model.run_image(&img).unwrap();
        assert_eq!(
            aot.as_i32().unwrap(),
            ours.as_slice(),
            "round {round}: functional simulator diverged from AOT artifact"
        );
    }
}

#[test]
fn fixed_artifact_tracks_float_artifact() {
    let Some(rt) = runtime() else { return };
    let fx = rt.engine("swin_micro_fixed_b1.hlo.txt").unwrap();
    let fl = rt.engine("swin_micro_float_b1.hlo.txt").unwrap();
    let mut rng = Rng::new(505);
    let img: Vec<f32> = (0..56 * 56 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
    let qi = fx.run(&[Tensor::F32(img.clone())]).unwrap();
    let ff = fl.run(&[Tensor::F32(img)]).unwrap();
    let q = qi.as_i32().unwrap();
    let f = ff.as_f32().unwrap();
    assert_eq!(q.len(), f.len());
    for (i, (&qv, &fv)) in q.iter().zip(f).enumerate() {
        let qf = qv as f32 / 256.0;
        assert!(
            (qf - fv).abs() < 0.05,
            "logit {i}: fixed {qf} vs float {fv}"
        );
    }
}

#[test]
fn weight_store_covers_micro_parameter_tree() {
    let Some(dir) = artifacts_dir() else { return };
    let ws = load_weights(&dir);
    // spot-check structure implied by configs.MICRO
    for name in [
        "patch_embed.wq",
        "patch_embed.bq",
        "stages.0.blocks.0.attn.wqkv",
        "stages.0.blocks.1.mlp.w2q",
        "stages.0.merge.wq",
        "stages.1.blocks.1.attn.rel_bias_q",
        "head.wq",
        "head.bq",
    ] {
        assert!(ws.tensors.contains_key(name), "missing {name}");
    }
    let wqkv = ws.matrix("stages.0.blocks.0.attn.wqkv").unwrap();
    assert_eq!(wqkv.shape, vec![32, 96]);
    let head = ws.matrix("head.wq").unwrap();
    assert_eq!(head.shape, vec![64, 10]);
}
