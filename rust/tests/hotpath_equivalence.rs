//! Differential suite for the allocation-free serving hot path (ISSUE 5):
//! every fast path introduced by the cost-table / event-calendar refactor
//! is pinned **bit-for-bit** to the code it replaced.
//!
//! * [`CostTable`] entries equal direct
//!   `PipelineSchedule::{launch_cycles, steady_launch_cycles}` for every
//!   variant × bucket × overlap-flag combination, and every consumer
//!   (`SimEngine`, `ServicePrior`) reads the same numbers through it;
//! * `steady_launch_cycles` (now an O(units) warm-append with
//!   placer-state fixed-point detection) returns an increment that stays
//!   stable under further appended launches — the k≤8 convergence
//!   regression — for every combination;
//! * the event-calendar router ([`Router::run_classed`]) reproduces the
//!   retained pre-calendar scan oracle ([`Router::run_classed_scan`])
//!   bit-identically — completions, served counts, sheds, percentiles —
//!   on the PR-3/PR-4 asserted fleet workloads, including the exact
//!   350.73 ms (warm backlog) / 350.79 ms (cold backlog) / 599.5 ms
//!   (busy-horizon) p99s;
//! * cached u64 prices equal the per-call `Duration` round-trip
//!   reference at every bucket and queue depth;
//! * (PR 9) `LoadModel::Energy` at zero weight with idle gating off
//!   reproduces `Backlog` bit-for-bit on the same workloads — the
//!   energy tentpole's differential oracle — and the J/inference the
//!   router prices with equals watts × launch span recomputed
//!   independently through `span_power_w`.
//!
//! No modelled number changes anywhere in this PR — that is the
//! acceptance criterion this suite enforces.

use swin_fpga::accel::pipeline::{CostTable, PipelineSchedule};
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{SwinVariant, BASE, MICRO, SMALL, TINY};
use swin_fpga::server::router::{
    completion_latencies_ms, fleet_capacity_fps, hetero_ts_fleet, hetero_ts_fleet_scaled,
    hetero_ts_fleet_scaled_send, percentile, FleetCompletion, FleetPolicy, LoadModel, Policy,
    Router, ShardSpec, ShardedRouter,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival, ClassedArrival};
use swin_fpga::server::{Engine, ServicePrior, SimEngine, BUCKET_SIZES};

static VARIANTS: [&SwinVariant; 4] = [&MICRO, &TINY, &SMALL, &BASE];

fn flag_cfgs() -> [AccelConfig; 3] {
    [
        AccelConfig::paper(),
        AccelConfig::paper().interlaunch(false),
        AccelConfig::paper().sequential(),
    ]
}

/// CostTable entries == direct schedule computation, for every variant ×
/// bucket × flag combination — and the serving consumers agree.
#[test]
fn cost_table_equals_schedule_everywhere() {
    for v in VARIANTS {
        for cfg in flag_cfgs() {
            let schedule = PipelineSchedule::for_variant(v, cfg.clone());
            let table = CostTable::for_variant(v, cfg.clone(), &BUCKET_SIZES);
            let sim = SimEngine::new(0, v, cfg.clone(), 0.0);
            let prior = ServicePrior::for_variant(v, cfg.clone());
            for b in BUCKET_SIZES {
                let cold = schedule.launch_cycles(b);
                let warm = schedule.steady_launch_cycles(b);
                assert_eq!(table.cold_cycles(b), cold, "{} b={b}", v.name);
                assert_eq!(table.warm_cycles(b), warm, "{} b={b}", v.name);
                // engine + prior read the identical numbers through the
                // shared table (Duration views of the same cycles)
                assert_eq!(sim.launch_cycles(b), cold, "{} b={b}", v.name);
                assert_eq!(sim.steady_launch_cycles(b), warm, "{} b={b}", v.name);
                assert_eq!(prior.estimate(b), sim.service_estimate(b), "{} b={b}", v.name);
                assert_eq!(
                    prior.steady_estimate(b),
                    sim.steady_estimate(b),
                    "{} b={b}",
                    v.name
                );
            }
        }
    }
}

/// The k≤8 convergence regression: the steady increment must be the true
/// fixed point — stable under one more appended launch — for every
/// variant × bucket × flag combination.
#[test]
fn steady_increment_stable_under_one_more_launch() {
    for v in VARIANTS {
        for cfg in flag_cfgs() {
            let s = PipelineSchedule::for_variant(v, cfg.clone());
            for b in BUCKET_SIZES {
                let steady = s.steady_launch_cycles(b);
                // appended far past any transient the old loop could
                // have bailed inside
                let k = 12usize;
                let total_k = s.sequence_cycles(&vec![b; k]);
                let total_k1 = s.sequence_cycles(&vec![b; k + 1]);
                let total_k2 = s.sequence_cycles(&vec![b; k + 2]);
                assert_eq!(
                    total_k1 - total_k,
                    steady,
                    "{} b={b} interlaunch={}: increment unstable at k={k}",
                    v.name,
                    cfg.overlap_interlaunch
                );
                assert_eq!(total_k2 - total_k1, steady, "{} b={b}", v.name);
            }
        }
    }
}

/// The engines' u64 cycle fast path must round-trip exactly like the
/// Duration API it shadows (the default impl IS the round-trip; this
/// guards any future override drifting).
#[test]
fn cycle_fast_path_equals_duration_round_trip() {
    const CYCLES_PER_MS: f64 = 200_000.0;
    let to_cycles = |d: std::time::Duration| (d.as_secs_f64() * 1e3 * CYCLES_PER_MS).round() as u64;
    for v in [&TINY, &SMALL] {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
            let e = SimEngine::new(0, v, cfg, 0.0);
            for b in [1usize, 2, 4, 8, 13, 16] {
                assert_eq!(
                    e.service_estimate_cycles(b, CYCLES_PER_MS),
                    to_cycles(e.service_estimate(b)),
                    "{} b={b}",
                    v.name
                );
                assert_eq!(
                    e.steady_estimate_cycles(b, CYCLES_PER_MS),
                    to_cycles(e.steady_estimate(b)),
                    "{} b={b}",
                    v.name
                );
            }
        }
    }
}

fn assert_identical(fast: &[FleetCompletion], slow: &[FleetCompletion], label: &str) {
    assert_eq!(fast.len(), slow.len(), "{label}: completion count");
    for (f, s) in fast.iter().zip(slow) {
        assert_eq!(
            (f.idx, f.device, f.class, f.arrival, f.start, f.finish),
            (s.idx, s.device, s.class, s.arrival, s.start, s.finish),
            "{label}: completion diverged"
        );
    }
}

/// The PR-3/PR-4 fleet workload: 2×Swin-T + 2×Swin-S, bursty at 2× the
/// fleet's modelled capacity, 500 requests, interactive share 0.5,
/// seed 31 — the exact arrivals the asserted p99s come from.
fn canonical_arrivals(cfg: &AccelConfig, n: usize) -> Vec<ClassedArrival> {
    let cap = fleet_capacity_fps(&hetero_ts_fleet(cfg));
    classed_arrivals(
        Arrival::Bursty {
            high: 2.0 * cap,
            burst_s: 0.2,
            gap_s: 0.3,
        },
        n,
        0.5,
        31,
    )
}

/// The tentpole differential on the canonical fleet workloads: the
/// event-calendar router reproduces the scan oracle bit for bit — warm
/// and cold timing, both load signals, and the 16-card hot-path scale.
#[test]
fn calendar_equals_scan_on_canonical_fleet_workloads() {
    for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
        let arr = canonical_arrivals(&cfg, 500);
        for load in [LoadModel::Backlog, LoadModel::BusyHorizon] {
            let mut r =
                Router::from_engines(hetero_ts_fleet(&cfg), Policy::LeastLoaded).with_load(load);
            let fast = r.run_classed(&arr);
            let served: Vec<u64> = r.served().to_vec();
            let shed = r.shed_count();
            let slow = r.run_classed_scan(&arr);
            let label = format!(
                "interlaunch={} load={}",
                cfg.overlap_interlaunch,
                load.name()
            );
            assert_identical(&fast, &slow, &label);
            assert_eq!(served, r.served(), "{label}: served counts");
            assert_eq!(shed, r.shed_count(), "{label}: shed counts");
            // summary statistics follow from identity, but pin the ones
            // the experiments report
            let (a, b) = (
                completion_latencies_ms(&fast),
                completion_latencies_ms(&slow),
            );
            for p in [0.50, 0.95, 0.99] {
                assert_eq!(percentile(&a, p), percentile(&b, p), "{label} p{p}");
            }
        }
    }
    // the hot-path bench scale: 16 cards, heavier stream
    let cfg = AccelConfig::paper();
    let engines = || hetero_ts_fleet_scaled(&cfg, 4);
    let cap = fleet_capacity_fps(&engines());
    let arr = classed_arrivals(
        Arrival::Bursty {
            high: 2.0 * cap,
            burst_s: 0.2,
            gap_s: 0.3,
        },
        2_000,
        0.5,
        31,
    );
    let mut r = Router::from_engines(engines(), Policy::LeastLoaded).with_load(LoadModel::Backlog);
    let fast = r.run_classed(&arr);
    let slow = r.run_classed_scan(&arr);
    assert_identical(&fast, &slow, "16-card hot-path workload");
}

/// ISSUE-7 chain on the canonical fleet workloads:
/// sharded(threads=k) == sharded(threads=1), and with one shard the
/// sharded router degenerates to the calendar — which stays pinned to
/// the scan oracle. Together: sharded == calendar == scan.
#[test]
fn sharded_chain_on_canonical_fleet_workloads() {
    let cfg = AccelConfig::paper();
    let arr = canonical_arrivals(&cfg, 500);
    let sharded = |shards: usize| {
        ShardedRouter::with_fleet(
            hetero_ts_fleet_scaled_send(&cfg, 1),
            Policy::LeastLoaded,
            FleetPolicy::default(),
            ShardSpec::new(shards, 10.0),
        )
    };
    for load in [LoadModel::Backlog, LoadModel::BusyHorizon] {
        let label = format!("load={}", load.name());
        // one shard (threads clamp to the shard count): == calendar
        let one = sharded(1).with_load(load).run_classed(&arr, 4);
        let mut r =
            Router::from_engines(hetero_ts_fleet(&cfg), Policy::LeastLoaded).with_load(load);
        let calendar = r.run_classed(&arr);
        let scan = r.run_classed_scan(&arr);
        assert_identical(&one, &calendar, &format!("{label}: sharded(1) vs calendar"));
        assert_identical(&calendar, &scan, &format!("{label}: calendar vs scan"));
        // two shards: the thread count is execution detail only
        let mut s = sharded(2).with_load(load);
        let base = s.run_classed(&arr, 1);
        for k in [2usize, 4] {
            let got = s.run_classed(&arr, k);
            assert_identical(&got, &base, &format!("{label}: threads={k} vs 1"));
        }
    }
}

/// The exact asserted PR-3/PR-4 p99s through the sharded entry point:
/// a single-shard [`ShardedRouter`] run on several threads must land on
/// the very same canonical numbers as the calendar router.
#[test]
fn canonical_p99s_via_the_sharded_router() {
    let warm_cfg = AccelConfig::paper();
    let cold_cfg = AccelConfig::paper().interlaunch(false);
    let arr = canonical_arrivals(&warm_cfg, 500);
    let p99_of = |cfg: &AccelConfig, load: LoadModel| -> f64 {
        let mut s = ShardedRouter::with_fleet(
            hetero_ts_fleet_scaled_send(cfg, 1),
            Policy::LeastLoaded,
            FleetPolicy::default(),
            ShardSpec::new(1, 10.0),
        )
        .with_load(load);
        let comps = s.run_classed(&arr, 2);
        assert_eq!(comps.len(), 500);
        percentile(&completion_latencies_ms(&comps), 0.99)
    };
    let warm = p99_of(&warm_cfg, LoadModel::Backlog);
    let cold = p99_of(&cold_cfg, LoadModel::Backlog);
    let busy = p99_of(&warm_cfg, LoadModel::BusyHorizon);
    assert!((warm - 350.73).abs() < 0.005, "warm backlog p99: {warm:.3}");
    assert!((cold - 350.79).abs() < 0.005, "cold backlog p99: {cold:.3}");
    assert!((busy - 599.5).abs() < 0.05, "busy-horizon p99: {busy:.2}");
}

/// The exact asserted PR-3/PR-4 p99s — no modelled number changes in
/// this PR. Values as recorded by the PR-4 acceptance run (2 dp for the
/// backlog pair, 1 dp for busy-horizon).
#[test]
fn canonical_p99s_are_reproduced_exactly() {
    let warm_cfg = AccelConfig::paper();
    let cold_cfg = AccelConfig::paper().interlaunch(false);
    let arr = canonical_arrivals(&warm_cfg, 500);
    let p99_of = |cfg: &AccelConfig, load: LoadModel| -> f64 {
        let mut r = Router::from_engines(hetero_ts_fleet(cfg), Policy::LeastLoaded).with_load(load);
        let comps = r.run_classed(&arr);
        assert_eq!(comps.len(), 500);
        percentile(&completion_latencies_ms(&comps), 0.99)
    };
    let warm = p99_of(&warm_cfg, LoadModel::Backlog);
    let cold = p99_of(&cold_cfg, LoadModel::Backlog);
    let busy = p99_of(&warm_cfg, LoadModel::BusyHorizon);
    assert!(
        (warm - 350.73).abs() < 0.005,
        "warm backlog p99 drifted: {warm:.3} ms (expected 350.73)"
    );
    assert!(
        (cold - 350.79).abs() < 0.005,
        "cold backlog p99 drifted: {cold:.3} ms (expected 350.79)"
    );
    assert!(
        (busy - 599.5).abs() < 0.05,
        "busy-horizon p99 drifted: {busy:.2} ms (expected 599.5)"
    );
}

/// PR-9 tentpole oracle: `LoadModel::Energy` at zero weight with idle
/// gating off IS the latency-only `Backlog` router — bit-for-bit on the
/// canonical fleet workloads, including the exact PR-3/PR-4 pinned
/// p99s (350.73 ms warm / 350.79 ms cold), with identical booked launch
/// energy, and still pinned to the scan oracle.
#[test]
fn energy_at_zero_weight_is_backlog_on_canonical_workloads() {
    let warm_cfg = AccelConfig::paper();
    let cold_cfg = AccelConfig::paper().interlaunch(false);
    let arr = canonical_arrivals(&warm_cfg, 500);
    for (cfg, pin) in [(&warm_cfg, 350.73), (&cold_cfg, 350.79)] {
        let label = format!("interlaunch={}", cfg.overlap_interlaunch);
        let mut b = Router::from_engines(hetero_ts_fleet(cfg), Policy::LeastLoaded)
            .with_load(LoadModel::Backlog);
        let backlog = b.run_classed(&arr);
        let mut e = Router::from_engines(hetero_ts_fleet(cfg), Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(0)
            .with_idle_gating(false);
        let energy = e.run_classed(&arr);
        assert_identical(&energy, &backlog, &label);
        assert_eq!(b.served(), e.served(), "{label}: served counts");
        assert_eq!(
            b.energy_spent_uj(),
            e.energy_spent_uj(),
            "{label}: booked launch energy"
        );
        assert!(b.energy_spent_uj() > 0, "{label}: energy accounting is live");
        let p99 = percentile(&completion_latencies_ms(&energy), 0.99);
        assert!(
            (p99 - pin).abs() < 0.005,
            "{label}: energy-model p99 {p99:.3} ms (expected {pin})"
        );
        // ... and the retained pre-calendar scan oracle agrees under the
        // energy model too
        let scan = e.run_classed_scan(&arr);
        assert_identical(&energy, &scan, &format!("{label}: energy vs scan"));
    }
    // the third canonical pin rides the untouched BusyHorizon signal
    let mut r = Router::from_engines(hetero_ts_fleet(&warm_cfg), Policy::LeastLoaded)
        .with_load(LoadModel::BusyHorizon);
    let busy = percentile(&completion_latencies_ms(&r.run_classed(&arr)), 0.99);
    assert!((busy - 599.5).abs() < 0.05, "busy-horizon p99: {busy:.2}");
}

/// PR-9 satellite: the J/inference the router prices with equals
/// watts × launch span recomputed independently through `span_power_w`
/// — per variant × bucket × nonlinear-unit design, cold and warm.
#[test]
fn engine_energy_equals_watts_times_span() {
    use swin_fpga::accel::nonlinear::NlDesign;
    use swin_fpga::accel::pipeline::Resource;
    use swin_fpga::accel::power::{span_power_w, SpanBusy};
    for v in VARIANTS {
        for d in NlDesign::ALL {
            let cfg = AccelConfig::paper().nonlinear(d);
            let e = SimEngine::new(0, v, cfg.clone(), 0.0);
            let s = PipelineSchedule::for_variant(v, cfg.clone());
            for b in BUCKET_SIZES {
                let busy = SpanBusy {
                    mmu: s.busy_batched(Resource::Mmu, b),
                    scu: s.busy_batched(Resource::Scu, b),
                    gcu: s.busy_batched(Resource::Gcu, b),
                    mru: s.busy_batched(Resource::Mru, b),
                };
                let spans = [(false, s.launch_cycles(b)), (true, s.steady_launch_cycles(b))];
                for (warm, span) in spans {
                    let watts = span_power_w(v, &cfg, busy, span);
                    // same association as power::launch_energy_j so the
                    // µJ round-trip is bit-exact, not merely close
                    let expect =
                        (watts * (span as f64 / (cfg.freq_mhz * 1e6)) * 1e6).round() as u64;
                    let got = if warm { e.steady_energy_uj(b) } else { e.launch_energy_uj(b) };
                    assert_eq!(
                        got,
                        expect,
                        "{} {} b={b} warm={warm}: engine µJ != watts × span",
                        v.name,
                        d.name()
                    );
                }
            }
        }
    }
}

/// Energy-model load prices (penalty + gated wake-up correction) equal
/// the engine-priced reference at every queue depth and clock reading.
#[test]
fn energy_load_prices_match_reference_under_gating() {
    for (weight, gate) in [(0u64, true), (5_000, false), (5_000, true)] {
        let mut r = Router::from_engines(hetero_ts_fleet(&AccelConfig::paper()), Policy::LeastLoaded)
            .with_load(LoadModel::Energy)
            .with_energy_weight(weight)
            .with_idle_gating(gate);
        for k in 0..9usize {
            r.seed_queue(
                k % 4,
                k,
                if k % 2 == 0 {
                    swin_fpga::server::Slo::Batch
                } else {
                    swin_fpga::server::Slo::Interactive
                },
                0,
            );
        }
        for now in [0u64, 1, 1_000, 10_000_000] {
            for i in 0..4 {
                assert_eq!(
                    r.load_cycles(i, now),
                    r.load_cycles_reference(i, now),
                    "weight={weight} gate={gate} card {i} now={now}"
                );
            }
        }
    }
}

/// Cached u64 prices equal the per-call Duration reference at every
/// bucket and queue depth, on the heterogeneous fleet.
#[test]
fn cached_prices_match_duration_reference_on_hetero_fleet() {
    let mut r = Router::from_engines(hetero_ts_fleet(&AccelConfig::paper()), Policy::LeastLoaded);
    for i in 0..4 {
        for n in 0..24usize {
            assert_eq!(
                r.queued_price_cycles(i, n),
                r.queued_price_cycles_reference(i, n),
                "card {i} queued={n}"
            );
        }
    }
    // seeded queues + mixed busy states, several clock readings
    for k in 0..9usize {
        r.seed_queue(
            k % 4,
            k,
            if k % 2 == 0 {
                swin_fpga::server::Slo::Batch
            } else {
                swin_fpga::server::Slo::Interactive
            },
            0,
        );
    }
    for now in [0u64, 1, 1_000, 10_000_000] {
        for i in 0..4 {
            assert_eq!(
                r.load_cycles(i, now),
                r.load_cycles_reference(i, now),
                "card {i} now={now}"
            );
        }
    }
}

/// (PR 10) An installed zero-fault plan is **inert** on the canonical
/// fleet workload: the faulted router reproduces the unfaulted run —
/// and the pinned 350.73 / 350.79 / 599.5 ms p99s — bit for bit, with
/// every fault counter at zero. The fault layer may not perturb a
/// single cycle until an event actually fires.
#[test]
fn zero_fault_plan_reproduces_canonical_p99s_bit_for_bit() {
    use swin_fpga::server::FaultPlan;
    let warm_cfg = AccelConfig::paper();
    let cold_cfg = AccelConfig::paper().interlaunch(false);
    let arr = canonical_arrivals(&warm_cfg, 500);
    let p99_of = |cfg: &AccelConfig, load: LoadModel, faulted: bool| -> f64 {
        let mut r = Router::from_engines(hetero_ts_fleet(cfg), Policy::LeastLoaded).with_load(load);
        if faulted {
            r = r.with_faults(FaultPlan::none(4));
        }
        let plain = r.run_classed(&arr);
        if faulted {
            let c = r.fault_counters();
            assert_eq!((c.retries, c.redispatched, c.crash_lost, c.lost), (0, 0, 0, 0));
            assert_eq!(r.health_counts(), [4, 0, 0, 0]);
        }
        percentile(&completion_latencies_ms(&plain), 0.99)
    };
    for (cfg, load, pin, tol) in [
        (&warm_cfg, LoadModel::Backlog, 350.73, 0.005),
        (&cold_cfg, LoadModel::Backlog, 350.79, 0.005),
        (&warm_cfg, LoadModel::BusyHorizon, 599.5, 0.05),
    ] {
        let base = p99_of(cfg, load, false);
        let with_plan = p99_of(cfg, load, true);
        assert_eq!(
            base.to_bits(),
            with_plan.to_bits(),
            "zero-fault plan perturbed the {} p99",
            load.name()
        );
        assert!((with_plan - pin).abs() < tol, "p99 drifted: {with_plan:.3} (expected {pin})");
    }
}
