//! Nonlinear-unit design-space invariants (PR tentpole acceptance):
//!
//! 1. the **baseline** design is bit-for-bit the pre-trait SCU/GCU —
//!    outputs, cycle formulas (vs an inline legacy oracle) and end-to-end
//!    cycle totals for the paper variants;
//! 2. **QUARK** shares the baseline circuit: identical numerics, less
//!    fabric, and — since the pipeline IR arbitrates the shared pipe
//!    per contended window instead of the old flat II=2 surcharge —
//!    identical cycles whenever softmax and GELU never co-live (true
//!    for every registry graph);
//! 3. **PEANO** has pinned accuracy goldens (it *beats* the baseline's
//!    LOD ripple) and dominates the baseline on power at equal-or-better
//!    cycles — the Pareto claim the `design_space` sweep reports;
//! 4. per-(unit × design) error statistics stay inside golden bands.

use swin_fpga::accel::nonlinear::{NlDesign, PEANO_DEPTH_SAVE};
use swin_fpga::accel::power::{
    accelerator_power_w, Activity, IDLE_ACTIVITY, W_PER_BRAM, W_PER_DSP, W_PER_KFF, W_PER_KLUT,
};
use swin_fpga::accel::resources::{accelerator_resources, Resources};
use swin_fpga::accel::scu::fmu_cycles;
use swin_fpga::accel::sim::{SimResult, Simulator};
use swin_fpga::accel::AccelConfig;
use swin_fpga::approx::error::{gelu_stats_for, softmax_stats_for};
use swin_fpga::approx::gelu::gelu_fixed;
use swin_fpga::approx::peano::{gelu_fixed_peano, softmax_row_peano, softmax_rows_peano};
use swin_fpga::approx::softmax::{softmax_row, softmax_rows};
use swin_fpga::model::config::{SwinVariant, BASE, REGISTRY, SMALL, TINY};
use swin_fpga::util::prng::Rng;

fn sim(v: &'static SwinVariant, d: NlDesign) -> SimResult {
    Simulator::new(v, AccelConfig::paper().nonlinear(d)).simulate_inference()
}

// --- 1. baseline ≡ the pre-trait implementation --------------------------

/// The legacy closed-form cycle model, reimplemented inline as an
/// oracle (these are the formulas `Scu`/`Gcu` hard-coded before the
/// design trait existed).
fn legacy_fmu(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let mut ready: Vec<u64> = Vec::new();
    let mut rem = n;
    while rem > 0 {
        let g = 1usize << (usize::BITS - 1 - rem.leading_zeros());
        ready.push(g.trailing_zeros() as u64);
        rem -= g;
    }
    while ready.len() > 1 {
        ready.sort_unstable();
        let a = ready.remove(0);
        let b = ready.remove(0);
        ready.push(a.max(b) + 1);
    }
    ready[0]
}

fn legacy_softmax_cycles(cfg: &AccelConfig, rows: usize, width: usize) -> u64 {
    rows as u64 * width.div_ceil(cfg.scu_lanes) as u64 + legacy_fmu(width) + cfg.scu_depth
}

fn legacy_gelu_cycles(cfg: &AccelConfig, elems: usize) -> u64 {
    elems.div_ceil(cfg.gcu_lanes) as u64 + cfg.gcu_depth
}

#[test]
fn baseline_cycle_formulas_match_the_legacy_oracle() {
    let cfg = AccelConfig::paper();
    let d = NlDesign::Baseline.design();
    for rows in [1usize, 49, 100, 3136] {
        for width in [7usize, 49, 64, 98] {
            assert_eq!(
                d.softmax_cycles(&cfg, rows, width),
                legacy_softmax_cycles(&cfg, rows, width),
                "rows={rows} width={width}"
            );
            // legacy exposed cost under overlap: fill only
            assert_eq!(
                d.softmax_exposed(&cfg, rows, width),
                legacy_fmu(width) + cfg.scu_depth
            );
        }
    }
    for elems in [0usize, 49, 490, 1_229_312] {
        assert_eq!(d.gelu_cycles(&cfg, elems), legacy_gelu_cycles(&cfg, elems));
        assert_eq!(d.gelu_exposed(&cfg, elems), cfg.gcu_depth);
    }
    // the shared FMU free fn is the same algorithm
    for n in [1usize, 2, 32, 49, 64, 128] {
        assert_eq!(fmu_cycles(n), legacy_fmu(n));
    }
    // PEANO is the baseline schedule with a shorter pipe fill: exactly
    // PEANO_DEPTH_SAVE cycles off both units at the paper depths
    let p = NlDesign::Peano.design();
    assert_eq!(
        p.softmax_cycles(&cfg, 49, 49),
        legacy_softmax_cycles(&cfg, 49, 49) - PEANO_DEPTH_SAVE
    );
    assert_eq!(
        p.gelu_cycles(&cfg, 490),
        legacy_gelu_cycles(&cfg, 490) - PEANO_DEPTH_SAVE
    );
}

#[test]
fn baseline_numerics_are_the_golden_kernels_bit_for_bit() {
    let d = NlDesign::Baseline.design();
    let mut rng = Rng::new(7);
    for width in [7usize, 49, 64] {
        let scores: Vec<i32> = (0..width * 20)
            .map(|_| (rng.normal() * 700.0) as i32)
            .collect();
        assert_eq!(d.softmax(&scores, width), softmax_rows(&scores, width));
    }
    let xs: Vec<i32> = (-1100..1100).map(|i| i as i32).collect();
    assert_eq!(
        d.gelu(&xs),
        xs.iter().map(|&x| gelu_fixed(x, false)).collect::<Vec<_>>()
    );
}

#[test]
fn baseline_end_to_end_totals_are_bit_identical_to_pre_refactor() {
    // pinned pre-refactor totals (the seed's cycle model, asserted
    // exactly — any drift in the baseline design is a regression)
    for (v, total) in [
        (&TINY, 4_534_362u64),
        (&SMALL, 7_589_036),
        (&BASE, 12_986_338),
    ] {
        let r = sim(v, NlDesign::Baseline);
        assert_eq!(r.total_cycles, total, "{}", v.name);
    }
}

#[test]
fn every_registry_variant_simulates_identically_under_default_config() {
    // AccelConfig::paper() *is* the baseline design: an explicit
    // Baseline selection must change nothing for any registry variant
    for v in REGISTRY {
        let a = Simulator::new(v, AccelConfig::paper()).simulate_inference();
        let b = sim(v, NlDesign::Baseline);
        assert_eq!(a.total_cycles, b.total_cycles, "{}", v.name);
        assert_eq!(a.nonlinear_cycles, b.nonlinear_cycles, "{}", v.name);
        assert_eq!(a.nonlinear_exposed, b.nonlinear_exposed, "{}", v.name);
        assert_eq!(a.scu_cycles, b.scu_cycles, "{}", v.name);
        assert_eq!(a.gcu_cycles, b.gcu_cycles, "{}", v.name);
    }
}

// --- 2. QUARK: same bits, different schedule -----------------------------

#[test]
fn quark_outputs_are_bit_identical_to_baseline() {
    let b = NlDesign::Baseline.design();
    let q = NlDesign::Quark.design();
    let mut rng = Rng::new(11);
    let scores: Vec<i32> = (0..49 * 50).map(|_| (rng.normal() * 700.0) as i32).collect();
    assert_eq!(q.softmax(&scores, 49), b.softmax(&scores, 49));
    let xs: Vec<i32> = (-1100..1100).map(|i| i as i32).collect();
    assert_eq!(q.gelu(&xs), b.gelu(&xs));
}

#[test]
fn per_design_cycle_totals_pinned() {
    // the calibration table the README's Pareto section quotes.
    // QUARK column re-pinned with the per-window arbitration fix (PR 9):
    // the registry graphs never co-live softmax and GELU, so the shared
    // pipe charges zero contention and QUARK prices exactly at the
    // baseline (the old flat-II=2 model over-charged TINY by 152_928
    // and BASE by 127_344 cycles).
    let pins: [(&'static SwinVariant, [u64; 3]); 3] = [
        (&TINY, [4_534_362, 4_534_362, 4_534_242]),
        (&SMALL, [7_589_036, 7_589_036, 7_589_036]),
        (&BASE, [12_986_338, 12_986_338, 12_986_314]),
    ];
    for (v, totals) in pins {
        for (d, want) in NlDesign::ALL.into_iter().zip(totals) {
            let got = sim(v, d).total_cycles;
            assert_eq!(got, want, "{} {}", v.name, d.name());
        }
    }
}

// --- 3. power: measured utilisation + per-design footprints --------------

#[test]
fn measured_busy_fractions_match_the_schedule() {
    // (variant, mmu, scu, gcu, mru) for the baseline design
    let pins = [
        (&TINY, 0.664, 0.0099, 0.0258, 0.979),
        (&SMALL, 0.763, 0.0097, 0.0252, 1.0),
        (&BASE, 0.780, 0.0075, 0.0196, 0.998),
    ];
    for (v, mmu, scu, gcu, mru) in pins {
        let a = Activity::from_sim(&sim(v, NlDesign::Baseline));
        assert!((a.mmu - mmu).abs() < 0.02, "{} mmu={}", v.name, a.mmu);
        assert!((a.scu - scu).abs() < 0.005, "{} scu={}", v.name, a.scu);
        assert!((a.gcu - gcu).abs() < 0.005, "{} gcu={}", v.name, a.gcu);
        assert!((a.mru - mru).abs() < 0.025, "{} mru={}", v.name, a.mru);
    }
}

#[test]
fn per_design_power_pinned() {
    // Baseline and PEANO keep absolute pins. QUARK's old absolutes
    // ([10.025, 10.498, 10.890]) baked in the flat-II=2 busy-cycle
    // inflation; with per-window arbitration (PR 9) its schedule and
    // activity are *identical* to the baseline on the registry graphs,
    // so its power is pinned relationally instead: baseline minus
    // exactly the GCU fabric it sheds, at the baseline's GCU duty.
    let pins: [(&'static SwinVariant, f64, f64); 3] = [
        (&TINY, 10.238, 10.126),
        (&SMALL, 10.592, 10.480),
        (&BASE, 11.026, 10.915),
    ];
    for (v, base_w, peano_w) in pins {
        let power = |d: NlDesign| {
            let cfg = AccelConfig::paper().nonlinear(d);
            let r = sim(v, d);
            let act = Activity::from_sim(&r);
            (accelerator_power_w(v, &cfg, &r, act), act)
        };
        let (pb, ab) = power(NlDesign::Baseline);
        let (pq, aq) = power(NlDesign::Quark);
        let (pp, _) = power(NlDesign::Peano);
        assert!((pb - base_w).abs() < 0.05, "{} baseline: {pb} W", v.name);
        assert!((pp - peano_w).abs() < 0.05, "{} peano: {pp} W", v.name);
        assert_eq!(aq, ab, "{}: quark activity must match baseline", v.name);
        let cfg = AccelConfig::paper();
        let fabric_w = |r: Resources| {
            r.dsp as f64 * W_PER_DSP
                + r.lut as f64 / 1e3 * W_PER_KLUT
                + r.ff as f64 / 1e3 * W_PER_KFF
                + r.bram as f64 * W_PER_BRAM
        };
        let shed = fabric_w(NlDesign::Baseline.design().gcu_resources(&cfg))
            - fabric_w(NlDesign::Quark.design().gcu_resources(&cfg));
        let duty = IDLE_ACTIVITY + (1.0 - IDLE_ACTIVITY) * ab.gcu;
        assert!(
            (pb - pq - shed * duty).abs() < 1e-6,
            "{}: quark {pq} W vs baseline {pb} W, expected delta {}",
            v.name,
            shed * duty
        );
    }
}

#[test]
fn paper_config_power_stays_inside_table5_bands() {
    // satellite 1 acceptance: real utilisation in, Table V bands hold
    for (v, paper, band) in [(&TINY, 10.69, 1.2), (&SMALL, 10.69, 1.2), (&BASE, 11.11, 1.3)] {
        let cfg = AccelConfig::paper();
        let r = sim(v, NlDesign::Baseline);
        let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
        assert!((p - paper).abs() < band, "{}: {p} W", v.name);
    }
}

#[test]
fn design_resource_totals_pinned() {
    // TINY config; BASE adds the wide-infra DSPs (+6) on top
    let tiny: [u32; 3] = [1727, 1678, 1666];
    for (d, want) in NlDesign::ALL.into_iter().zip(tiny) {
        let cfg = AccelConfig::paper().nonlinear(d);
        assert_eq!(accelerator_resources(&TINY, &cfg).dsp, want, "{}", d.name());
        assert_eq!(
            accelerator_resources(&BASE, &cfg).dsp,
            want + 6,
            "{}",
            d.name()
        );
    }
}

// --- 4. accuracy goldens per (unit × design) -----------------------------

#[test]
fn softmax_error_goldens() {
    let base = softmax_stats_for(softmax_row, 100, 49, 3.0, 9);
    let peano = softmax_stats_for(softmax_row_peano, 100, 49, 3.0, 9);
    let quark = softmax_stats_for(
        |row, out| out.copy_from_slice(&NlDesign::Quark.design().softmax(row, row.len())),
        100,
        49,
        3.0,
        9,
    );
    // golden bands (python-mirror cross-checked; loose enough for libm
    // rounding differences in the f64 reference, tight enough to catch
    // any kernel change)
    assert!((base.max_err - 0.042943).abs() < 2e-3, "{base:?}");
    assert!((base.mean_err - 0.00058761).abs() < 2e-4, "{base:?}");
    assert!((base.max_sum_dev - 0.055511).abs() < 2e-3, "{base:?}");
    assert!((peano.max_err - 0.026308).abs() < 2e-3, "{peano:?}");
    assert!((peano.mean_err - 0.00021885).abs() < 2e-4, "{peano:?}");
    assert!((peano.max_sum_dev - 0.031677).abs() < 2e-3, "{peano:?}");
    // QUARK is the shared baseline circuit: identical stats, exactly
    assert_eq!(quark, base);
    // the PEANO reciprocal beats the baseline's LOD ripple end to end
    assert!(peano.max_err < base.max_err);
    assert!(peano.mean_err < base.mean_err);
    assert!(peano.max_sum_dev < base.max_sum_dev);
}

#[test]
fn gelu_error_goldens() {
    let base = gelu_stats_for(|q| gelu_fixed(q, false), -4.0, 4.0, 0.01);
    let peano = gelu_stats_for(gelu_fixed_peano, -4.0, 4.0, 0.01);
    let quark = gelu_stats_for(
        |q| NlDesign::Quark.design().gelu(&[q])[0],
        -4.0,
        4.0,
        0.01,
    );
    assert!((base.max_abs - 0.173329).abs() < 2e-3, "{base:?}");
    assert!((base.mean_abs - 0.03421705).abs() < 5e-4, "{base:?}");
    assert!((peano.max_abs - 0.126587).abs() < 2e-3, "{peano:?}");
    assert!((peano.mean_abs - 0.02958556).abs() < 5e-4, "{peano:?}");
    assert_eq!(quark, base);
    assert!(peano.max_abs < base.max_abs);
    assert!(peano.mean_abs < base.mean_abs);
}

// --- 5. the Pareto claim -------------------------------------------------

#[test]
fn peano_dominates_baseline_on_power_at_equal_or_better_cycles() {
    // acceptance: at least one alternative dominates the baseline on
    // power at equal-or-better cycles, with accuracy inside the pinned
    // bounds (here: strictly better accuracy, see the golden tests)
    for v in [&TINY, &SMALL, &BASE] {
        let rb = sim(v, NlDesign::Baseline);
        let rp = sim(v, NlDesign::Peano);
        assert!(rp.total_cycles <= rb.total_cycles, "{}", v.name);
        let cb = AccelConfig::paper();
        let cp = AccelConfig::paper().nonlinear(NlDesign::Peano);
        let pb = accelerator_power_w(v, &cb, &rb, Activity::from_sim(&rb));
        let pp = accelerator_power_w(v, &cp, &rp, Activity::from_sim(&rp));
        assert!(pp < pb, "{}: peano {pp} W vs baseline {pb} W", v.name);
    }
}

#[test]
fn peano_row_and_matrix_kernels_agree() {
    let mut rng = Rng::new(3);
    let scores: Vec<i32> = (0..49 * 8).map(|_| (rng.normal() * 700.0) as i32).collect();
    let m = softmax_rows_peano(&scores, 49);
    for (i, chunk) in scores.chunks(49).enumerate() {
        let mut out = vec![0i32; 49];
        softmax_row_peano(chunk, &mut out);
        assert_eq!(&m[i * 49..(i + 1) * 49], &out[..]);
    }
}
