//! Timing-unification suite: the pipeline schedule IR
//! (`accel::pipeline::PipelineSchedule`) is the crate's single timing
//! source, so every consumer — simulator, trace renderer, serving
//! engines, router estimates — must agree with it *exactly*. These tests
//! enforce the cross-module equalities plus the pipelining invariants
//! (prefetch never slower than sequential, never faster than the
//! serialized resource chains allow).

use std::time::Duration;

use swin_fpga::accel::pipeline::{PipelineSchedule, Resource};
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::trace::{Timeline, Unit};
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{BASE, MICRO, SMALL, TINY};
use swin_fpga::server::{Engine, ServicePrior, SimEngine, BUCKET_SIZES};

fn both_modes() -> [AccelConfig; 2] {
    [AccelConfig::paper(), AccelConfig::paper().sequential()]
}

#[test]
fn timeline_busy_equals_sim_result_for_every_resource() {
    for cfg in both_modes() {
        for v in [&MICRO, &TINY, &SMALL] {
            let t = Timeline::capture(v, cfg.clone());
            let r = Simulator::new(v, cfg.clone()).simulate_inference();
            assert_eq!(t.busy(Unit::Mmu), r.mmu_cycles, "{} mmu", v.name);
            assert_eq!(t.busy(Unit::Mru), r.mem_cycles, "{} mru", v.name);
            assert_eq!(t.busy(Unit::Scu), r.scu_cycles, "{} scu", v.name);
            assert_eq!(t.busy(Unit::Gcu), r.gcu_cycles, "{} gcu", v.name);
            assert_eq!(t.total_cycles, r.total_cycles, "{} total", v.name);
        }
    }
}

#[test]
fn pipelined_latency_bounded_by_sequential_and_resources() {
    for v in [&MICRO, &TINY, &SMALL, &BASE] {
        let pipe = PipelineSchedule::for_variant(v, AccelConfig::paper());
        let seq = PipelineSchedule::for_variant(v, AccelConfig::paper().sequential());
        // overlap can only help…
        assert!(
            pipe.total_cycles <= seq.total_cycles,
            "{}: pipelined {} > sequential {}",
            v.name,
            pipe.total_cycles,
            seq.total_cycles
        );
        // …but never beats the serialized resource chains (MMU+exposed
        // nonlinear on the compute side, MRU streaming on the memory side)
        let compute_chain: u64 = pipe.units.iter().map(|u| u.compute).sum();
        let stream_chain = pipe.busy(Resource::Mru);
        assert!(pipe.total_cycles >= compute_chain, "{}", v.name);
        assert!(pipe.total_cycles >= stream_chain, "{}", v.name);
    }
}

#[test]
fn sim_engine_launch_cost_is_the_schedule_launch_cost() {
    for cfg in both_modes() {
        for v in [&MICRO, &TINY] {
            let e = SimEngine::new(0, v, cfg.clone(), 0.0);
            let s = PipelineSchedule::for_variant(v, cfg.clone());
            for b in BUCKET_SIZES {
                assert_eq!(e.launch_cycles(b), s.launch_cycles(b), "{} b={b}", v.name);
            }
            assert_eq!(e.launch_cycles(1), s.total_cycles, "{}", v.name);
        }
    }
}

#[test]
fn router_service_estimates_flow_from_the_schedule() {
    for cfg in both_modes() {
        let e = SimEngine::new(0, &TINY, cfg.clone(), 0.0);
        let s = PipelineSchedule::for_variant(&TINY, cfg);
        for b in [1usize, 4, 8] {
            let want = Duration::from_secs_f64(s.launch_ms(b) / 1e3);
            assert_eq!(e.service_estimate(b), want, "b={b}");
        }
    }
}

#[test]
fn cold_start_prior_within_2x_of_independent_bound() {
    // ROADMAP: PjrtEngine's first-launch estimate is warmed from the
    // cycle model (ServicePrior) instead of a 5 ms guess. The prior and
    // SimEngine share the schedule, so the meaningful check is against
    // an independently derived latency window: at least the streamed
    // bytes over the effective bandwidth, at most 2x that (the design
    // is bandwidth-bound, so modelled latency hugs the memory floor).
    use swin_fpga::model::graph::WorkloadGraph;
    for v in [&MICRO, &TINY, &SMALL] {
        let cfg = AccelConfig::paper();
        let g = WorkloadGraph::build(v);
        let bytes = (g.total_weight_bytes() + g.total_activation_bytes()) as f64;
        let floor_cycles = (bytes / cfg.effective_bw()).ceil() as u64;
        let floor_s = cfg.cycles_to_ms(floor_cycles) / 1e3;
        let p = ServicePrior::for_variant(v, cfg.clone())
            .estimate(1)
            .as_secs_f64();
        assert!(p >= floor_s * 0.999, "{}: {p} under {floor_s}", v.name);
        assert!(p <= 2.0 * floor_s, "{}: {p} over 2x {floor_s}", v.name);
        // wiring: the warm estimate and the sim backend read one schedule
        let sim = SimEngine::new(0, v, cfg.clone(), 0.0);
        assert_eq!(
            ServicePrior::for_variant(v, cfg).estimate(1),
            sim.service_estimate(1),
            "{}",
            v.name
        );
    }
}

#[test]
fn batch_replay_monotone_and_stream_shared() {
    for cfg in both_modes() {
        for v in [&MICRO, &TINY, &BASE] {
            let s = PipelineSchedule::for_variant(v, cfg.clone());
            let mut prev_per_image = f64::INFINITY;
            for b in [1usize, 2, 4, 8] {
                let per = s.launch_cycles(b) as f64 / b as f64;
                assert!(
                    per <= prev_per_image,
                    "{} b={b}: per-image cost increased",
                    v.name
                );
                prev_per_image = per;
            }
            assert!(s.launch_cycles(8) < 8 * s.launch_cycles(1), "{}", v.name);
        }
    }
}

#[test]
fn stage_attribution_is_exact_for_all_variants() {
    // regression for the old `stage.min(stages - 1)` clamp in the
    // simulator: per-stage spans must cover every op with exact indices
    // and partition the total in both scheduling modes
    for cfg in both_modes() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let r = Simulator::new(v, cfg.clone()).simulate_inference();
            assert_eq!(r.per_stage_cycles.len(), v.num_stages(), "{}", v.name);
            assert_eq!(
                r.per_stage_cycles.iter().sum::<u64>(),
                r.total_cycles,
                "{}",
                v.name
            );
            assert!(
                r.per_stage_cycles.iter().all(|&c| c > 0),
                "{}: empty stage in {:?}",
                v.name,
                r.per_stage_cycles
            );
        }
    }
}

#[test]
fn sequential_mode_reproduces_unit_local_costs() {
    // the ablation contract: with overlap_interunit off, the launch is
    // exactly the sum of per-unit critical paths (old sequential numbers)
    let s = PipelineSchedule::for_variant(&TINY, AccelConfig::paper().sequential());
    let unit_critical = |replicas: u64| -> u64 {
        s.units
            .iter()
            .map(|u| {
                let (compute, stream) = (replicas * u.compute, u.mem);
                compute.max(stream)
            })
            .sum()
    };
    assert_eq!(s.total_cycles, unit_critical(1));
    for b in [2u64, 8] {
        assert_eq!(s.launch_cycles(b as usize), unit_critical(b), "b={b}");
    }
}
