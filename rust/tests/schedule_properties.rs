//! Property/differential suite over the whole timing stack — the
//! pipeline/sequence IR now has enough consumers (simulator, trace,
//! serving engines, router backlog pricing) that its invariants get a
//! dedicated randomized harness instead of per-PR spot checks.
//!
//! Trials are seeded (`util::prng`) and deterministic: the seed comes
//! from `SWIN_PROP_SEED` when set (CI pins it) and a fixed default
//! otherwise, so a failure always reproduces.

use swin_fpga::accel::buffers::{BufferPlan, XCZU19EG_BRAM36};
use swin_fpga::accel::pipeline::{PipelineSchedule, Resource, Segment};
use swin_fpga::accel::shard::{ShardPlan, ShardedSchedule};
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{SwinVariant, BASE, BASE_384, LARGE_384, MICRO, SMALL, TINY};
use swin_fpga::util::prng::Rng;

static VARIANTS: [&SwinVariant; 4] = [&MICRO, &TINY, &SMALL, &BASE];
const BATCHES: [usize; 4] = [1, 2, 4, 8];

fn seed() -> u64 {
    std::env::var("SWIN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// One random trial point: variant, flag combination, launch sequence.
struct Trial {
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    batches: Vec<usize>,
}

fn random_trial(rng: &mut Rng) -> Trial {
    let variant = VARIANTS[rng.below(VARIANTS.len() as u64) as usize];
    let mut cfg = AccelConfig::paper();
    cfg.overlap_nonlinear = rng.below(2) == 0;
    cfg.overlap_interunit = rng.below(2) == 0;
    cfg.overlap_interlaunch = rng.below(2) == 0;
    let len = 1 + rng.below(4) as usize;
    let batches = (0..len)
        .map(|_| BATCHES[rng.below(BATCHES.len() as u64) as usize])
        .collect();
    Trial {
        variant,
        cfg,
        batches,
    }
}

fn schedule(t: &Trial) -> PipelineSchedule {
    PipelineSchedule::for_variant(t.variant, t.cfg.clone())
}

/// No two segments of one hardware resource may overlap, across the
/// whole multi-launch timeline: each engine is one physical unit.
#[test]
fn no_two_segments_on_one_resource_overlap() {
    let mut rng = Rng::new(seed());
    for trial in 0..24 {
        let t = random_trial(&mut rng);
        let s = schedule(&t);
        let seq = s.sequence(&t.batches);
        let segs = s.sequence_segments(&seq);
        for r in Resource::ALL {
            let mut busy: Vec<(u64, u64, &str)> = segs
                .iter()
                .filter(|e| e.unit == r)
                .map(|e| (e.start, e.end, e.label.as_str()))
                .collect();
            busy.sort();
            for w in busy.windows(2) {
                assert!(
                    w[1].0 >= w[0].1,
                    "trial {trial} {} {:?} {}: {:?} overlaps {:?}",
                    t.variant.name,
                    t.batches,
                    r.name(),
                    w[0],
                    w[1]
                );
            }
        }
        // every segment stays inside the sequence window
        for e in &segs {
            assert!(e.end >= e.start);
            assert!(e.end <= seq.total_cycles, "{} overruns", e.label);
        }
    }
}

/// With cross-launch prefetch off, a sequence is exactly the sum of its
/// single-launch totals, bit for bit — the PR-2 per-launch contract.
#[test]
fn barrier_sequences_sum_single_launch_totals_exactly() {
    let mut rng = Rng::new(seed() ^ 1);
    for _ in 0..24 {
        let mut t = random_trial(&mut rng);
        t.cfg.overlap_interlaunch = false;
        let s = schedule(&t);
        let want: u64 = t.batches.iter().map(|&b| s.launch_cycles(b)).sum();
        assert_eq!(
            s.sequence_cycles(&t.batches),
            want,
            "{} {:?}",
            t.variant.name,
            t.batches
        );
    }
}

/// Pipelining can only help: a warm sequence never exceeds the barrier
/// sequence on the same batches, and a cross-unit-pipelined launch never
/// exceeds the sequential one by more than its cold entry fill (the one
/// constraint the pre-IR sequential calibration does not model).
#[test]
fn pipelined_timings_never_slower() {
    let mut rng = Rng::new(seed() ^ 2);
    for _ in 0..24 {
        let t = random_trial(&mut rng);
        let mut warm_cfg = t.cfg.clone();
        warm_cfg.overlap_interlaunch = true;
        let mut cold_cfg = t.cfg.clone();
        cold_cfg.overlap_interlaunch = false;
        let warm = PipelineSchedule::for_variant(t.variant, warm_cfg);
        let cold = PipelineSchedule::for_variant(t.variant, cold_cfg);
        assert!(
            warm.sequence_cycles(&t.batches) <= cold.sequence_cycles(&t.batches),
            "{} {:?}",
            t.variant.name,
            t.batches
        );
        let pipe = PipelineSchedule::for_variant(t.variant, AccelConfig::paper());
        let seq = PipelineSchedule::for_variant(t.variant, AccelConfig::paper().sequential());
        let fill = pipe.units[0].mem.min(pipe.window_fills[pipe.units[0].stage]);
        for &b in &t.batches {
            assert!(
                pipe.launch_cycles(b) <= seq.launch_cycles(b) + fill,
                "{} b={b}: {} vs {} + fill {fill}",
                t.variant.name,
                pipe.launch_cycles(b),
                seq.launch_cycles(b)
            );
        }
    }
}

/// Every prefetch start respects the BufferPlan headroom constraint:
/// unit *g*'s stream may not begin before the unit `depth(stage)` places
/// ahead of it released its weight-buffer slot. The gate is recomputed
/// here from `BufferPlan` directly — if the schedule ever hard-codes
/// slack again, this drifts and fails.
#[test]
fn prefetch_starts_respect_buffer_headroom() {
    let mut rng = Rng::new(seed() ^ 3);
    for _ in 0..24 {
        let mut t = random_trial(&mut rng);
        // headroom gating is a property of the pipelined placements;
        // barrier resets make the global history non-monotone
        t.cfg.overlap_interlaunch = true;
        let s = schedule(&t);
        let plan = BufferPlan::for_variant(t.variant);
        assert_eq!(s.prefetch_depths, plan.prefetch_depths(), "{}", t.variant.name);
        let seq = s.sequence(&t.batches);
        // global unit order: launches back to back, schedule units within
        let mut ce_hist: Vec<u64> = Vec::new();
        for launch in &seq.launches {
            for (u, sp) in s.units.iter().zip(&launch.spans) {
                let depth = plan.prefetch_depth(u.stage);
                if ce_hist.len() >= depth {
                    let slot_free = ce_hist[ce_hist.len() - depth];
                    assert!(
                        sp.stream_start >= slot_free,
                        "{} {:?}: {} streams at {} before slot frees at {slot_free}",
                        t.variant.name,
                        t.batches,
                        u.label,
                        sp.stream_start
                    );
                }
                ce_hist.push(sp.compute_end);
            }
        }
    }
}

/// `stage_spans` still partitions the launch total exactly, for every
/// variant × batch × flag combination.
#[test]
fn stage_spans_partition_the_total_everywhere() {
    let mut rng = Rng::new(seed() ^ 4);
    for _ in 0..24 {
        let t = random_trial(&mut rng);
        let s = schedule(&t);
        let stages = t.variant.num_stages();
        for &b in &t.batches {
            let spans = s.stage_spans(stages, b);
            assert_eq!(
                spans.iter().sum::<u64>(),
                s.launch_cycles(b),
                "{} b={b}",
                t.variant.name
            );
        }
    }
}

/// Warm steady-state cost: never above cold; equal when the flag is off;
/// strictly below at the full bucket for the paper variants (the
/// acceptance claim — the warm entry skips the cold window fill).
#[test]
fn steady_state_cost_vs_cold_launch() {
    for v in VARIANTS {
        let warm = PipelineSchedule::for_variant(v, AccelConfig::paper());
        let cold = PipelineSchedule::for_variant(v, AccelConfig::paper().interlaunch(false));
        for b in BATCHES {
            assert!(warm.steady_launch_cycles(b) <= warm.launch_cycles(b), "{}", v.name);
            assert_eq!(cold.steady_launch_cycles(b), cold.launch_cycles(b));
            // cold per-launch totals do not depend on the flag
            assert_eq!(warm.launch_cycles(b), cold.launch_cycles(b));
        }
        assert!(
            warm.steady_launch_cycles(8) < warm.launch_cycles(8),
            "{}: warm {} !< cold {}",
            v.name,
            warm.steady_launch_cycles(8),
            warm.launch_cycles(8)
        );
    }
}

/// Sequence totals are monotone: appending a launch strictly grows the
/// timeline, and per-resource busy cycles scale per launch.
#[test]
fn sequences_grow_monotonically() {
    let mut rng = Rng::new(seed() ^ 5);
    for _ in 0..16 {
        let t = random_trial(&mut rng);
        let s = schedule(&t);
        let mut prefix: Vec<usize> = Vec::new();
        let mut prev = 0u64;
        for &b in &t.batches {
            prefix.push(b);
            let total = s.sequence_cycles(&prefix);
            assert!(total > prev, "{} {:?}", t.variant.name, prefix);
            prev = total;
        }
        // MRU busy over the sequence = one shared stream per launch
        let seq = s.sequence(&t.batches);
        let mru: u64 = s
            .sequence_segments(&seq)
            .iter()
            .filter(|e| e.unit == Resource::Mru)
            .map(Segment::dur)
            .sum();
        assert_eq!(
            mru,
            t.batches.len() as u64 * s.busy(Resource::Mru),
            "{} {:?}",
            t.variant.name,
            t.batches
        );
    }
}

// --- sharded-pipeline invariants (the ShardPlan layer) ----------------

/// A random genuinely multi-shard trial: either a 384 variant that
/// overflows the XCZU19EG, or a paper variant forced to split by a
/// budget one block below its whole-model plan.
fn random_shard_trial(rng: &mut Rng) -> (Trial, usize) {
    let (variant, budget) = match rng.below(4) {
        0 => (&BASE_384, XCZU19EG_BRAM36),
        1 => (&LARGE_384, XCZU19EG_BRAM36),
        _ => {
            let v = VARIANTS[rng.below(VARIANTS.len() as u64) as usize];
            (v, BufferPlan::for_variant(v).total_bram36() - 1)
        }
    };
    let mut t = random_trial(rng);
    t.variant = variant;
    (t, budget)
}

fn sharded(t: &Trial, budget: usize) -> ShardedSchedule {
    ShardedSchedule::for_plan(ShardPlan::for_budget(t.variant, budget), t.cfg.clone())
}

/// Per-card resources never overlap (each card's MMU/MRU/SCU/GCU is one
/// physical unit — but shard 0's MMU and shard 1's MMU may overlap, that
/// is the point of pipeline parallelism), each link serialises its own
/// transfers, and every segment stays inside the sequence window.
#[test]
fn sharded_resources_never_overlap_within_a_card() {
    let mut rng = Rng::new(seed() ^ 6);
    for trial in 0..12 {
        let (t, budget) = random_shard_trial(&mut rng);
        let s = sharded(&t, budget);
        assert!(s.cards() >= 2, "trial {trial}: plan degenerated to one card");
        let seq = s.sequence(&t.batches);
        for k in 0..s.cards() {
            let segs = s.shard_segments(&seq, k);
            for r in Resource::ALL {
                let mut busy: Vec<(u64, u64, &str)> = segs
                    .iter()
                    .filter(|e| e.unit == r)
                    .map(|e| (e.start, e.end, e.label.as_str()))
                    .collect();
                busy.sort();
                for w in busy.windows(2) {
                    assert!(
                        w[1].0 >= w[0].1,
                        "trial {trial} {} shard {k} {}: {:?} overlaps {:?}",
                        t.variant.name,
                        r.name(),
                        w[0],
                        w[1]
                    );
                }
            }
            if k + 1 < s.cards() {
                let links = s.link_segments(&seq, k);
                for w in links.windows(2) {
                    assert!(
                        w[1].start >= w[0].end,
                        "trial {trial}: link {k} transfers overlap"
                    );
                }
            }
        }
        for e in s.sequence_segments(&seq) {
            assert!(e.end >= e.start);
            assert!(e.end <= seq.total_cycles, "{} overruns the window", e.label);
        }
    }
}

/// A single-shard plan lowers **bit-for-bit** to the unsharded schedule,
/// under every flag combination and batch mix: same launch totals, same
/// steady increments, same per-unit spans.
#[test]
fn single_shard_plans_lower_bit_for_bit() {
    let mut rng = Rng::new(seed() ^ 7);
    for _ in 0..12 {
        let t = random_trial(&mut rng);
        let plan = ShardPlan::for_variant(t.variant);
        assert!(plan.is_single(), "{} should fit one card", t.variant.name);
        let shd = ShardedSchedule::for_plan(plan, t.cfg.clone());
        let flat = schedule(&t);
        for &b in &t.batches {
            assert_eq!(shd.launch_cycles(b), flat.launch_cycles(b), "b={b}");
            assert_eq!(
                shd.steady_launch_cycles(b),
                flat.steady_launch_cycles(b),
                "b={b}"
            );
        }
        assert_eq!(
            shd.sequence_cycles(&t.batches),
            flat.sequence_cycles(&t.batches)
        );
        let seq = shd.sequence(&t.batches);
        let flat_seq = flat.sequence(&t.batches);
        for (l, fl) in seq.launches.iter().zip(&flat_seq.launches) {
            assert!(l.links.is_empty());
            for (a, b) in l.shards[0].spans.iter().zip(&fl.spans) {
                assert_eq!(
                    (a.stream_start, a.stream_end, a.compute_start, a.compute_end),
                    (b.stream_start, b.stream_end, b.compute_start, b.compute_end),
                    "{} {:?}",
                    t.variant.name,
                    t.batches
                );
            }
        }
    }
}

/// The converged sharded steady increment is the slowest component's
/// rate — the max over every shard's own steady increment and every
/// link's transfer time. Throughput of the sharded pipeline is the
/// slowest shard's throughput ("min over shards"), never better.
#[test]
fn sharded_steady_is_the_slowest_component_rate() {
    let mut rng = Rng::new(seed() ^ 8);
    for trial in 0..12 {
        let (t, budget) = random_shard_trial(&mut rng);
        let s = sharded(&t, budget);
        for &b in &t.batches {
            let slowest = s
                .shards
                .iter()
                .map(|sh| sh.steady_launch_cycles(b))
                .chain((0..s.cards() - 1).map(|k| s.link_cycles(k, b)))
                .max()
                .unwrap();
            assert_eq!(
                s.steady_launch_cycles(b),
                slowest,
                "trial {trial} {} b={b} interunit={} interlaunch={}",
                t.variant.name,
                t.cfg.overlap_interunit,
                t.cfg.overlap_interlaunch
            );
        }
    }
}

/// A link transfer never starts before its producer shard completes the
/// launch, runs exactly its modelled duration, and the consumer shard
/// honours the chunked per-image gate (PR 9): its first replica waits
/// for its own first chunk — not the whole serialised batch — and it
/// cannot drain before the last chunk lands. Batch 1 degenerates to the
/// pre-chunking full-transfer gate.
#[test]
fn links_never_precede_their_producers() {
    let mut rng = Rng::new(seed() ^ 9);
    for trial in 0..12 {
        let (t, budget) = random_shard_trial(&mut rng);
        let s = sharded(&t, budget);
        let seq = s.sequence(&t.batches);
        for l in &seq.launches {
            for (k, &(start, end)) in l.links.iter().enumerate() {
                assert!(
                    start >= l.shards[k].end,
                    "trial {trial}: link {k} outruns its producer"
                );
                assert_eq!(end - start, s.link_cycles(k, l.batch));
                assert!(
                    l.shards[k + 1].spans[0].compute_start >= start + s.link_cycles(k, 1),
                    "trial {trial}: shard {} computes before chunk 0 of link {k} lands",
                    k + 1
                );
                assert!(
                    l.shards[k + 1].spans[0].compute_end >= end,
                    "trial {trial}: shard {} drains before link {k} finishes",
                    k + 1
                );
                if l.batch == 1 {
                    assert!(
                        l.shards[k + 1].spans[0].compute_start >= end,
                        "trial {trial}: batch-1 gate must be the full transfer"
                    );
                }
            }
        }
    }
}

/// PR-9 link-chunking fix, randomized: re-place every random sharded
/// sequence under the pre-chunking gate (downstream compute waits for
/// the FULL serialised batch-`b` block) and require the chunked timeline
/// to never be slower — and to be bit-identical when every launch is
/// batch 1, where the chunked gate degenerates to the full transfer.
#[test]
fn chunked_link_gate_never_slower_than_the_serialized_gate() {
    use swin_fpga::accel::pipeline::SequencePlacer;
    let serialized_end = |s: &ShardedSchedule, batches: &[usize]| -> u64 {
        let mut placers: Vec<SequencePlacer> = s
            .shards
            .iter()
            .map(|sh| SequencePlacer::new(sh.as_ref()))
            .collect();
        let mut link_free = vec![0u64; s.cards().saturating_sub(1)];
        let mut end = 0u64;
        for &b in batches {
            let mut input_ready = 0u64;
            for k in 0..placers.len() {
                let l = placers[k].append_gated(b, input_ready);
                if k + 1 < placers.len() {
                    let dur = s.link_cycles(k, b);
                    let start = l.end.max(link_free[k]);
                    link_free[k] = start + dur;
                    input_ready = start + dur;
                }
                end = l.end;
            }
        }
        end
    };
    let mut rng = Rng::new(seed() ^ 11);
    for trial in 0..12 {
        let (t, budget) = random_shard_trial(&mut rng);
        let s = sharded(&t, budget);
        let new = s.sequence_cycles(&t.batches);
        let old = serialized_end(&s, &t.batches);
        assert!(
            new <= old,
            "trial {trial} {} {:?}: chunked {new} > serialized {old}",
            t.variant.name,
            t.batches
        );
        let ones = vec![1usize; t.batches.len()];
        assert_eq!(
            s.sequence_cycles(&ones),
            serialized_end(&s, &ones),
            "trial {trial} {}: batch-1 sequences must be bit-identical",
            t.variant.name
        );
    }
}

/// PR-9 QUARK arbitration fix, randomized: the shared-pipe design prices
/// ops at sole-ownership (baseline) rates and charges only genuinely
/// contended windows, so for every variant × flags × bucket its launch
/// sits between the baseline and the old flat-II=2 over-charge
/// (baseline + the whole SCU+GCU busy time again), and the registry
/// graphs — where softmax and GELU never co-live — price exactly at the
/// baseline. Peano stays untouched by the arbitration pass.
#[test]
fn quark_arbitration_bounded_by_baseline_and_flat_ii2() {
    use swin_fpga::accel::nonlinear::NlDesign;
    let mut rng = Rng::new(seed() ^ 12);
    for _ in 0..16 {
        let t = random_trial(&mut rng);
        let base = PipelineSchedule::for_variant(t.variant, t.cfg.clone().nonlinear(NlDesign::Baseline));
        let quark = PipelineSchedule::for_variant(t.variant, t.cfg.clone().nonlinear(NlDesign::Quark));
        for &b in &t.batches {
            let (bc, qc) = (base.launch_cycles(b), quark.launch_cycles(b));
            // contention only ever adds cycles...
            assert!(qc >= bc, "{} b={b}: quark {qc} < baseline {bc}", t.variant.name);
            // ...and never more than re-serialising every nonlinear
            // window (the old flat-II=2 model's upper bound)
            let nl_busy = base.busy(Resource::Scu) + base.busy(Resource::Gcu);
            assert!(
                qc <= bc + b.max(1) as u64 * nl_busy,
                "{} b={b}: quark {qc} over-charges past flat II=2",
                t.variant.name
            );
        }
    }
    // the registry graphs never co-schedule softmax and GELU windows:
    // arbitration finds zero contention and quark == baseline exactly
    for v in [&TINY, &SMALL, &BASE] {
        let base = PipelineSchedule::for_variant(v, AccelConfig::paper());
        let quark =
            PipelineSchedule::for_variant(v, AccelConfig::paper().nonlinear(NlDesign::Quark));
        let peano_a =
            PipelineSchedule::for_variant(v, AccelConfig::paper().nonlinear(NlDesign::Peano));
        for b in BATCHES {
            assert_eq!(
                quark.launch_cycles(b),
                base.launch_cycles(b),
                "{} b={b}: registry graphs have no co-liveness to charge",
                v.name
            );
            assert_eq!(quark.steady_launch_cycles(b), base.steady_launch_cycles(b));
            // peano's cycles come from its shorter fill, not arbitration
            assert!(peano_a.launch_cycles(b) <= base.launch_cycles(b), "{}", v.name);
        }
    }
}

/// ISSUE-7 determinism property, randomized: for random heterogeneous
/// fleets, shard counts and workloads, the sharded router's completion
/// stream is identical for every thread count, and the single-shard
/// case degenerates to the event-calendar router — which must itself
/// match the retained scan oracle. The full chain
/// `sharded(k) == sharded(1) == calendar == scan` on every trial.
#[test]
fn sharded_router_chain_holds_on_random_fleets_and_workloads() {
    use std::sync::Arc;
    use swin_fpga::accel::pipeline::CostTable;
    use swin_fpga::server::router::{
        FleetPolicy, LoadModel, Policy, Router, ShardSpec, ShardedRouter,
    };
    use swin_fpga::server::workload::{classed_arrivals, Arrival};
    use swin_fpga::server::{Engine, SimEngine, BUCKET_SIZES};

    let cfg = AccelConfig::paper();
    let card_variants: [&SwinVariant; 3] = [&MICRO, &TINY, &SMALL];
    let tables: Vec<Arc<CostTable>> = card_variants
        .iter()
        .map(|v| Arc::new(CostTable::for_variant(v, cfg.clone(), &BUCKET_SIZES)))
        .collect();
    let mut rng = Rng::new(seed() ^ 10);
    for trial in 0..8 {
        // random heterogeneous fleet (2..=9 cards) as index picks, so
        // the Send and non-Send builds are the *same* fleet
        let cards = 2 + rng.below(8) as usize;
        let picks: Vec<usize> = (0..cards)
            .map(|_| rng.below(card_variants.len() as u64) as usize)
            .collect();
        let send_fleet = |picks: &[usize]| -> Vec<Box<dyn Engine + Send>> {
            picks
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    Box::new(SimEngine::with_table(
                        i,
                        card_variants[w],
                        Arc::clone(&tables[w]),
                        0.0,
                    )) as Box<dyn Engine + Send>
                })
                .collect()
        };
        let shards = 1 + rng.below(cards as u64) as usize;
        let policy = [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo]
            [rng.below(3) as usize];
        let load = [LoadModel::Backlog, LoadModel::BusyHorizon, LoadModel::Energy]
            [rng.below(3) as usize];
        // random energy pricing + gating for the Energy rows (weight 0
        // with gating off is the Backlog-identity corner, also sampled)
        let (weight, gate) = if load == LoadModel::Energy {
            (rng.below(4) * 10_000, rng.below(2) == 0)
        } else {
            (0, false)
        };
        let n = 150 + rng.below(250) as usize;
        let wl_seed = rng.next_u64();
        let kind = match rng.below(3) {
            0 => Arrival::Poisson { rate: 40.0 + rng.f64() * 400.0 },
            1 => Arrival::Periodic { fps: 40.0 + rng.f64() * 200.0 },
            _ => Arrival::Bursty {
                high: 100.0 + rng.f64() * 600.0,
                burst_s: 0.05 + rng.f64() * 0.3,
                gap_s: 0.05 + rng.f64() * 0.4,
            },
        };
        let arr = classed_arrivals(kind, n, rng.f64(), wl_seed);
        let label = format!(
            "trial {trial}: cards={cards} shards={shards} {} {} w={weight} gate={gate} n={n}",
            policy.name(),
            load.name()
        );

        // thread-count invariance at the random shard count
        let mut s = ShardedRouter::with_fleet(
            send_fleet(&picks),
            policy,
            FleetPolicy::default(),
            ShardSpec::new(shards, 5.0),
        )
        .with_load(load)
        .with_energy_weight(weight)
        .with_idle_gating(gate);
        let base = s.run_classed(&arr, 1);
        let base_energy = s.energy_spent_uj();
        for k in [2usize, 3, 8] {
            let got = s.run_classed(&arr, k);
            assert_eq!(got.len(), base.len(), "{label}: threads={k} count");
            assert_eq!(s.energy_spent_uj(), base_energy, "{label}: threads={k} energy");
            for (a, b) in got.iter().zip(&base) {
                assert_eq!(
                    (a.idx, a.device, a.class, a.arrival, a.start, a.finish),
                    (b.idx, b.device, b.class, b.arrival, b.start, b.finish),
                    "{label}: threads={k} diverged"
                );
            }
        }

        // single-shard degeneracy: == calendar == scan on the same fleet
        let mut one = ShardedRouter::with_fleet(
            send_fleet(&picks),
            policy,
            FleetPolicy::default(),
            ShardSpec::new(1, 5.0),
        )
        .with_load(load)
        .with_energy_weight(weight)
        .with_idle_gating(gate);
        let got = one.run_classed(&arr, 2);
        let engines: Vec<Box<dyn Engine>> = send_fleet(&picks)
            .into_iter()
            .map(|e| {
                let e: Box<dyn Engine> = e;
                e
            })
            .collect();
        let mut r = Router::from_engines(engines, policy)
            .with_load(load)
            .with_energy_weight(weight)
            .with_idle_gating(gate);
        let calendar = r.run_classed(&arr);
        let scan = r.run_classed_scan(&arr);
        assert_eq!(got.len(), calendar.len(), "{label}: sharded(1) vs calendar count");
        assert_eq!(calendar.len(), scan.len(), "{label}: calendar vs scan count");
        for ((a, b), c) in got.iter().zip(&calendar).zip(&scan) {
            assert_eq!(
                (a.idx, a.device, a.class, a.arrival, a.start, a.finish),
                (b.idx, b.device, b.class, b.arrival, b.start, b.finish),
                "{label}: sharded(1) vs calendar"
            );
            assert_eq!(
                (b.idx, b.device, b.class, b.arrival, b.start, b.finish),
                (c.idx, c.device, c.class, c.arrival, c.start, c.finish),
                "{label}: calendar vs scan"
            );
        }
        assert_eq!(one.shed_count(), r.shed_count(), "{label}: sheds");
        assert_eq!(one.served(), r.served().to_vec(), "{label}: served");
        assert_eq!(
            one.energy_spent_uj(),
            r.energy_spent_uj(),
            "{label}: sharded(1) vs calendar booked energy"
        );
    }
}

/// (PR 10) Seeded random fault plans over random fleets: the faulted
/// sharded chain `sharded(k) == sharded(1) == calendar == scan` holds
/// for threads {1, 2, 4} — completions, fault counters, and the health
/// census — and **conservation** holds on every trial: every submitted
/// request is served, shed at admission, or counted lost, exactly once.
#[test]
fn faulted_fleets_conserve_requests_and_match_across_shards() {
    use std::sync::Arc;
    use swin_fpga::accel::pipeline::CostTable;
    use swin_fpga::server::fault::ms_to_cycles;
    use swin_fpga::server::router::{
        FleetPolicy, LoadModel, Policy, Router, ShardSpec, ShardedRouter,
    };
    use swin_fpga::server::workload::{classed_arrivals, Arrival};
    use swin_fpga::server::{Engine, FaultPlan, SimEngine, BUCKET_SIZES};

    let cfg = AccelConfig::paper();
    let card_variants: [&SwinVariant; 3] = [&MICRO, &TINY, &SMALL];
    let tables: Vec<Arc<CostTable>> = card_variants
        .iter()
        .map(|v| Arc::new(CostTable::for_variant(v, cfg.clone(), &BUCKET_SIZES)))
        .collect();
    let mut rng = Rng::new(seed() ^ 11);
    for trial in 0..6 {
        let cards = 2 + rng.below(7) as usize;
        let picks: Vec<usize> = (0..cards)
            .map(|_| rng.below(card_variants.len() as u64) as usize)
            .collect();
        let send_fleet = |picks: &[usize]| -> Vec<Box<dyn Engine + Send>> {
            picks
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    Box::new(SimEngine::with_table(
                        i,
                        card_variants[w],
                        Arc::clone(&tables[w]),
                        0.0,
                    )) as Box<dyn Engine + Send>
                })
                .collect()
        };
        let shards = 1 + rng.below(cards as u64) as usize;
        let policy = [Policy::RoundRobin, Policy::LeastLoaded, Policy::PowerOfTwo]
            [rng.below(3) as usize];
        let load = [LoadModel::Backlog, LoadModel::BusyHorizon][rng.below(2) as usize];
        let n = 150 + rng.below(200) as usize;
        let wl_seed = rng.next_u64();
        let kind = Arrival::Bursty {
            high: 100.0 + rng.f64() * 500.0,
            burst_s: 0.05 + rng.f64() * 0.3,
            gap_s: 0.05 + rng.f64() * 0.4,
        };
        let arr = classed_arrivals(kind, n, rng.f64(), wl_seed);
        // fault horizon = the workload span, so events land mid-run
        let horizon = ms_to_cycles(arr.last().unwrap().t * 1e3).max(1);
        let plan = FaultPlan::random(rng.next_u64(), cards, horizon, rng.below(4) as u32);
        let label = format!(
            "trial {trial}: cards={cards} shards={shards} {} {} n={n} plan={plan:?}",
            policy.name(),
            load.name()
        );

        // thread-count invariance at the random shard count
        let mut s = ShardedRouter::with_fleet(
            send_fleet(&picks),
            policy,
            FleetPolicy::default(),
            ShardSpec::new(shards, 5.0),
        )
        .with_load(load)
        .with_faults(plan.clone());
        let base = s.run_classed(&arr, 1);
        let counters = s.fault_counters();
        let health = s.health_counts();
        for k in [2usize, 4] {
            let got = s.run_classed(&arr, k);
            assert_eq!(got.len(), base.len(), "{label}: threads={k} count");
            assert_eq!(s.fault_counters(), counters, "{label}: threads={k} counters");
            assert_eq!(s.health_counts(), health, "{label}: threads={k} health");
            for (a, b) in got.iter().zip(&base) {
                assert_eq!(a, b, "{label}: threads={k} diverged");
            }
        }

        // single-shard degeneracy: == calendar == scan under the plan
        let mut one = ShardedRouter::with_fleet(
            send_fleet(&picks),
            policy,
            FleetPolicy::default(),
            ShardSpec::new(1, 5.0),
        )
        .with_load(load)
        .with_faults(plan.clone());
        let got = one.run_classed(&arr, 2);
        let engines: Vec<Box<dyn Engine>> = send_fleet(&picks)
            .into_iter()
            .map(|e| {
                let e: Box<dyn Engine> = e;
                e
            })
            .collect();
        let mut r = Router::from_engines(engines, policy)
            .with_load(load)
            .with_faults(plan);
        let calendar = r.run_classed(&arr);
        let cal_counters = r.fault_counters();
        let cal_shed = r.shed_count();
        let scan = r.run_classed_scan(&arr);
        assert_eq!(got.len(), calendar.len(), "{label}: sharded(1) vs calendar count");
        assert_eq!(calendar.len(), scan.len(), "{label}: calendar vs scan count");
        for ((a, b), c) in got.iter().zip(&calendar).zip(&scan) {
            assert_eq!(a, b, "{label}: sharded(1) vs calendar");
            assert_eq!(b, c, "{label}: calendar vs scan");
        }
        assert_eq!(one.fault_counters(), cal_counters, "{label}: counters");
        assert_eq!(one.health_counts(), r.health_counts(), "{label}: health");
        assert_eq!(cal_counters, r.fault_counters(), "{label}: scan counters");

        // conservation: submitted == served + shed + lost
        assert_eq!(
            n as u64,
            calendar.len() as u64 + cal_shed + cal_counters.lost,
            "{label}: conservation"
        );
    }
}
