//! End-to-end serving tests: continuous batcher over real PJRT engines.
//!
//! Requires `artifacts/` and a real PJRT runtime; skips with a notice
//! when either is missing (the xla stub build). The artifact-free
//! equivalents of these tests run against `SimEngine` in
//! `serving_batcher.rs`, so the batcher itself is always covered.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use swin_fpga::server::{run_demo_metrics, BatchPolicy, Request, Server, Slo};
use swin_fpga::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing (run the AOT pipeline first)");
        None
    }
}

/// PJRT may be stubbed out even when artifacts exist; detect by trying to
/// start a server and skip on failure.
fn start_or_skip(dir: &std::path::Path, policy: BatchPolicy) -> Option<Server> {
    match Server::start(dir, policy) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: PJRT backend unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn serves_all_requests_with_sane_latency() {
    let Some(dir) = artifacts_dir() else { return };
    let m = match run_demo_metrics(&dir, 24, 200.0, BatchPolicy::default()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: PJRT backend unavailable: {e:#}");
            return;
        }
    };
    assert_eq!(m.completed, 24);
    assert_eq!(m.latencies_ms.len(), 24);
    assert!(m.percentile_ms(0.5) > 0.0);
    assert!(m.percentile_ms(0.99) < 10_000.0);
    // batch mix must cover all requests
    let served: u64 = m.batches.values().sum();
    assert_eq!(served, 24);
}

#[test]
fn batcher_forms_batches_under_load() {
    // slam the server faster than single-image latency: batches > 1 must
    // appear (that's the entire point of the dynamic batcher)
    let Some(dir) = artifacts_dir() else { return };
    let m = match run_demo_metrics(&dir, 32, 100_000.0, BatchPolicy::default()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: PJRT backend unavailable: {e:#}");
            return;
        }
    };
    assert_eq!(m.completed, 32);
    let multi: u64 = m
        .batches
        .iter()
        .filter(|(&s, _)| s > 1)
        .map(|(_, &c)| c)
        .sum();
    assert!(multi > 0, "no multi-request batches formed: {:?}", m.batches);
}

#[test]
fn single_request_roundtrip_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(server) = start_or_skip(
        &dir,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    ) else {
        return;
    };
    let (tx, rx) = mpsc::channel();
    let mut rng = Rng::new(1);
    let image: Vec<f32> = (0..56 * 56 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
    server
        .submit(
            Request {
                id: 7,
                image,
                enqueued: Instant::now(),
                class: Slo::Interactive,
            },
            tx,
        )
        .unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(resp.id, 7);
    assert_eq!(resp.logits.len(), 10); // micro: 10 classes
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    server.shutdown().unwrap();
}

#[test]
fn deterministic_logits_across_batch_sizes() {
    // the same image must classify identically whether served alone or
    // inside a batch (engines share identical fused weights)
    let Some(dir) = artifacts_dir() else { return };
    if start_or_skip(&dir, BatchPolicy::default()).is_none() {
        return;
    }
    let mut rng = Rng::new(9);
    let image: Vec<f32> = (0..56 * 56 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();

    let run_with = |max_batch: usize, burst: usize| -> Vec<f32> {
        let server = Server::start(
            &dir,
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(5),
                ..Default::default()
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        for id in 0..burst {
            server
                .submit(
                    Request {
                        id: id as u64,
                        image: image.clone(),
                        enqueued: Instant::now(),
                        class: Slo::Interactive,
                    },
                    tx.clone(),
                )
                .unwrap();
        }
        drop(tx);
        let mut first = None;
        for resp in rx.iter().take(burst) {
            if resp.id == 0 {
                first = Some(resp.logits);
            }
        }
        server.shutdown().unwrap();
        first.unwrap()
    };

    let solo = run_with(1, 1);
    let batched = run_with(8, 8);
    for (a, b) in solo.iter().zip(&batched) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}
