//! Continuous-batching serving tests over the simulated backend — these
//! always run (no artifacts, no PJRT needed) and cover the batcher
//! semantics the PJRT-gated `server_e2e` suite can only exercise when a
//! real runtime is present:
//!
//! * the flush deadline is armed from the **oldest** queued request's
//!   `enqueued` instant (regression: a timer re-armed per arrival starves
//!   flushes past `max_wait` under a steady trickle);
//! * continuous admission beats the seed's stop-the-world
//!   accumulate/flush cycle at equal `max_wait`/`max_batch`;
//! * bounded-queue backpressure (shed vs block);
//! * batch formation, occupancy accounting and logits determinism;
//! * SLO classes: under overload, interactive traffic keeps a bounded
//!   wait (its class deadline plus one launch) and a far better tail
//!   than batch traffic, while batch traffic never starves;
//! * the fleet acceptance experiment: per-card batcher queues routed by
//!   modelled **backlog** beat the raw busy-horizon signal on p99 over a
//!   heterogeneous Swin-T/S fleet under bursty load;
//! * the warm-vs-cold ablation (ISSUE 4): with cross-launch prefetch on,
//!   back-to-back launches pay the warm steady-state cost and the
//!   warm-priced backlog router beats or matches the cold
//!   (`overlap_interlaunch = false`, i.e. PR-3) p99 on the same
//!   workload.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{MICRO, TINY};
use swin_fpga::server::router::{
    class_latencies_ms, completion_latencies_ms, fleet_capacity_fps, hetero_ts_fleet,
    percentile, LoadModel, Policy, Router, CYCLES_PER_MS,
};
use swin_fpga::server::workload::{arrivals, classed_arrivals, merge_classed, Arrival};
use swin_fpga::server::{
    run_demo_metrics_sim, BatchMode, BatchPolicy, Engine, Metrics, Overload, Request, Response,
    Server, SimEngine, Slo, SloPolicy,
};

const MICRO_IMG: usize = 56 * 56 * 3;
const TINY_IMG: usize = 224 * 224 * 3;

fn micro_server(policy: BatchPolicy) -> Server {
    Server::start_sim(&MICRO, AccelConfig::paper(), 0.0, policy).unwrap()
}

fn img(len: usize, salt: f32) -> Vec<f32> {
    (0..len).map(|i| (i % 17) as f32 * 0.03 + salt).collect()
}

fn submit_one(server: &Server, id: u64, image: Vec<f32>, tx: &mpsc::Sender<Response>) -> bool {
    submit_classed(server, id, image, Slo::Interactive, tx)
}

fn submit_classed(
    server: &Server,
    id: u64,
    image: Vec<f32>,
    class: Slo,
    tx: &mpsc::Sender<Response>,
) -> bool {
    server
        .submit(Request::new(id, image).with_class(class), tx.clone())
        .unwrap()
}

fn collect(rx: &mpsc::Receiver<Response>, n: usize) -> Vec<Response> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(rx.recv_timeout(Duration::from_secs(30)).expect("response"));
    }
    out
}

#[test]
fn burst_is_served_completely_and_batched() {
    let server = micro_server(BatchPolicy::default());
    let (tx, rx) = mpsc::channel();
    for id in 0..32 {
        assert!(submit_one(&server, id, img(MICRO_IMG, 0.0), &tx));
    }
    let resps = collect(&rx, 32);
    server.shutdown().unwrap();
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..32).collect::<Vec<_>>());
    // a 32-burst must produce multi-request launches
    assert!(
        resps.iter().any(|r| r.batch > 1),
        "no multi-request batches in a 32-burst"
    );
    // every launch is fully accounted: occupancy <= batch, depth >= occupancy
    for r in &resps {
        assert!(r.occupancy >= 1 && r.occupancy <= r.batch);
        assert!(r.queue_depth >= r.occupancy);
        assert_eq!(r.logits.len(), 10);
    }
}

#[test]
fn same_image_same_logits_regardless_of_batching() {
    let server = micro_server(BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        ..Default::default()
    });
    let (tx, rx) = mpsc::channel();
    let shared = img(MICRO_IMG, 0.25);
    for id in 0..8 {
        submit_one(&server, id, shared.clone(), &tx);
    }
    let batched = collect(&rx, 8);
    // now alone
    submit_one(&server, 99, shared.clone(), &tx);
    let solo = collect(&rx, 1).remove(0);
    server.shutdown().unwrap();
    for r in &batched {
        assert_eq!(r.logits, solo.logits, "req {} diverged", r.id);
    }
}

/// Regression (ISSUE 1): the flush timer must be armed from the *oldest*
/// queued request's `enqueued` instant. A timer re-armed on each arrival
/// never fires under a steady trickle with gap < max_wait — the first
/// request would wait `gaps × n + max_wait` instead of `max_wait`.
#[test]
fn deadline_armed_from_oldest_not_rearmed_per_arrival() {
    let max_wait = Duration::from_millis(120);
    let server = micro_server(BatchPolicy {
        max_batch: 8,
        max_wait,
        ..Default::default()
    });
    let (tx, rx) = mpsc::channel();
    // paced submitter: 8 requests, one every 30 ms — a steady trickle
    let gap = Duration::from_millis(30);
    let t0 = Instant::now();
    for id in 0..8u64 {
        submit_one(&server, id, img(MICRO_IMG, id as f32 * 0.01), &tx);
        thread::sleep(gap);
    }
    let resps = collect(&rx, 8);
    server.shutdown().unwrap();
    let first = resps.iter().find(|r| r.id == 0).expect("first response");
    // armed-from-oldest: the first request flushes ~max_wait after its own
    // enqueue. The buggy re-arm policy would push it past
    // 7 × 30 ms + 120 ms = 330 ms.
    assert!(
        first.latency < Duration::from_millis(300),
        "first request starved: waited {:?} (deadline re-armed per arrival?)",
        first.latency
    );
    assert!(
        first.latency >= max_wait,
        "flushed before the max_wait window elapsed: {:?}",
        first.latency
    );
    // the window actually batched the trickle that arrived inside it
    assert!(
        first.occupancy >= 2,
        "deadline flush did not batch the trickle: occupancy {}",
        first.occupancy
    );
    // sanity on total duration: everything finished promptly
    assert!(t0.elapsed() < Duration::from_secs(5));
}

/// Continuous admission beats the seed's stop-the-world accumulate/flush
/// cycle at equal max_wait and max_batch: a burst larger than one bucket
/// plus a straggler. Stop-the-world idles a full `max_wait` before its
/// first launch (window below `max_batch`) and freezes admission across
/// its whole plan; continuous launches the first full bucket immediately.
#[test]
fn continuous_outperforms_stop_the_world() {
    // TINY at time_scale 0.2: launch(8) sleeps ~24 ms, launch(1) ~5 ms —
    // large enough that scheduler jitter is noise
    let run = |mode: BatchMode| -> Metrics {
        let server = Server::start_sim(
            &TINY,
            AccelConfig::paper(),
            0.2,
            BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_millis(50),
                mode,
                ..Default::default()
            },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        let image = img(TINY_IMG, 0.0);
        for id in 0..24u64 {
            submit_one(&server, id, image.clone(), &tx);
        }
        thread::sleep(Duration::from_millis(5));
        submit_one(&server, 24, image.clone(), &tx);
        let mut m = Metrics::default();
        for r in collect(&rx, 25) {
            m.record(&r);
        }
        m.wall = t0.elapsed();
        server.shutdown().unwrap();
        m
    };
    let cont = run(BatchMode::Continuous);
    let stw = run(BatchMode::StopTheWorld);
    // strictly higher sustained load: same work, meaningfully less wall
    assert!(
        cont.wall + Duration::from_millis(20) < stw.wall,
        "continuous {:?} vs stop-the-world {:?}",
        cont.wall,
        stw.wall
    );
    assert!(
        cont.throughput() > stw.throughput(),
        "continuous {:.1}/s vs stop-the-world {:.1}/s",
        cont.throughput(),
        stw.throughput()
    );
    // and lower median latency (stop-the-world waits out the window
    // deadline before its first launch)
    assert!(
        cont.percentile_ms(0.5) < stw.percentile_ms(0.5),
        "p50 {:.1} vs {:.1}",
        cont.percentile_ms(0.5),
        stw.percentile_ms(0.5)
    );
}

#[test]
fn shed_policy_bounds_the_queue() {
    // slow card (launch(1) sleeps ~25 ms), tiny queue, shed on overflow
    let server = Server::start_sim(
        &TINY,
        AccelConfig::paper(),
        1.0,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_cap: 2,
            overload: Overload::Shed,
            ..Default::default()
        },
    )
    .unwrap();
    let (tx, rx) = mpsc::channel();
    let image = img(TINY_IMG, 0.0);
    // let the executor start its first launch, then slam
    submit_one(&server, 0, image.clone(), &tx);
    thread::sleep(Duration::from_millis(8));
    let mut admitted = 1u64;
    for id in 1..20u64 {
        if submit_one(&server, id, image.clone(), &tx) {
            admitted += 1;
        }
    }
    let resps = collect(&rx, admitted as usize);
    server.shutdown().unwrap();
    let shed = 20 - admitted;
    assert!(shed >= 10, "expected heavy shedding, got {shed}");
    assert_eq!(resps.len(), admitted as usize);
}

#[test]
fn block_policy_completes_everything() {
    let server = Server::start_sim(
        &TINY,
        AccelConfig::paper(),
        0.02,
        BatchPolicy {
            queue_cap: 2,
            overload: Overload::Block,
            ..Default::default()
        },
    )
    .unwrap();
    let (tx, rx) = mpsc::channel();
    let image = img(TINY_IMG, 0.0);
    for id in 0..12u64 {
        assert!(submit_one(&server, id, image.clone(), &tx));
    }
    let resps = collect(&rx, 12);
    assert_eq!(server.shed_count(), 0, "Block policy must never shed");
    server.shutdown().unwrap();
    assert_eq!(resps.len(), 12);
    // with a queue capped far below the bucket size, launches stay small
    assert!(resps.iter().all(|r| r.batch <= 4), "unexpectedly large launch");
}

fn est_secs(e: &dyn Engine, b: usize) -> f64 {
    e.service_estimate(b).as_secs_f64()
}

fn cycles(secs: f64) -> u64 {
    (secs * 1e3 * CYCLES_PER_MS).round() as u64
}

/// SLO classes in virtual time (deterministic): a sparse interactive
/// trickle rides on a batch flood offered ~30% over full-bucket
/// capacity. Interactive requests keep their class guarantee — wait
/// bounded by `max_wait` plus one launch — and a far better tail than
/// batch traffic, while every batch request still completes.
#[test]
fn slo_interactive_bounded_batch_never_starved() {
    let cfg = AccelConfig::paper();
    let probe = SimEngine::new(0, &TINY, cfg.clone(), 0.0);
    let c8 = est_secs(&probe, 8);
    let batch_rate = 1.3 * 8.0 / c8; // 30% over one card's bucket-8 capacity
    let inter_rate = 0.5 / c8; // ~1 interactive per 2 launches
    let interactive = arrivals(Arrival::Poisson { rate: inter_rate }, 30, 17);
    let batch = arrivals(Arrival::Poisson { rate: batch_rate }, 400, 23);
    let arr = merge_classed(&interactive, &batch);

    let engines: Vec<Box<dyn Engine>> =
        vec![Box::new(SimEngine::new(0, &TINY, cfg.clone(), 0.0))];
    let mut r = Router::from_engines(engines, Policy::LeastLoaded);
    let comps = r.run_classed(&arr);
    assert_eq!(comps.len(), 430);
    let inter_lats = class_latencies_ms(&comps, Slo::Interactive);
    let batch_lats = class_latencies_ms(&comps, Slo::Batch);
    // batch traffic never starves: every request completes
    assert_eq!(inter_lats.len(), 30);
    assert_eq!(batch_lats.len(), 400);
    // interactive tail beats the batch tail under overload
    let p99_i = percentile(&inter_lats, 0.99);
    let p99_b = percentile(&batch_lats, 0.99);
    assert!(
        p99_i < p99_b,
        "interactive p99 {p99_i:.1} ms !< batch p99 {p99_b:.1} ms"
    );
    // the class guarantee: no interactive request waits past its
    // max_wait plus one (largest-bucket) launch
    let bound = cycles(SloPolicy::default().interactive_max_wait.as_secs_f64()) + cycles(c8);
    for c in comps.iter().filter(|c| c.class == Slo::Interactive) {
        assert!(
            c.wait_cycles() <= bound,
            "interactive idx {} waited {} cycles (> {bound})",
            c.idx,
            c.wait_cycles()
        );
    }
}

/// The PR-3 acceptance experiment: per-card batcher queues routed by
/// modelled backlog (decompose + service_estimate) vs the raw
/// busy-horizon signal, identical bursty arrivals, heterogeneous
/// Swin-T/S 4-card fleet. Backlog-aware JSQ must not lose on p99.
#[test]
fn backlog_routing_beats_busy_horizon_on_heterogeneous_fleet() {
    let cfg = AccelConfig::paper();
    let make = || hetero_ts_fleet(&cfg);
    // offered load scaled to the fleet's own modelled single-image
    // capacity; bursts overdrive it 2x with idle gaps between
    let cap = fleet_capacity_fps(&make());
    let kind = Arrival::Bursty {
        high: 2.0 * cap,
        burst_s: 0.2,
        gap_s: 0.3,
    };
    let arr = classed_arrivals(kind, 500, 0.5, 31);
    let p99_of = |load: LoadModel| -> f64 {
        let mut r = Router::from_engines(make(), Policy::LeastLoaded).with_load(load);
        let comps = r.run_classed(&arr);
        assert_eq!(comps.len(), 500, "{} lost requests", load.name());
        percentile(&completion_latencies_ms(&comps), 0.99)
    };
    let busy = p99_of(LoadModel::BusyHorizon);
    let backlog = p99_of(LoadModel::Backlog);
    assert!(
        backlog <= busy,
        "backlog-aware p99 {backlog:.1} ms lost to busy-horizon p99 {busy:.1} ms"
    );
}

/// The ISSUE-4 acceptance experiment: the same heterogeneous bursty
/// workload as the PR-3 test, with the launch-sequence IR's cross-launch
/// prefetch on (warm steady-state costs for back-to-back launches and
/// warm-priced backlog) vs off (`overlap_interlaunch = false`: every
/// launch pays the cold cost and a sequence is exactly the sum of
/// single launches — the pre-sequence-IR timing structure). Warm must
/// beat or match the cold p99: back-to-back launches only get cheaper
/// when launch N+1's weights stream while launch N computes.
#[test]
fn warm_priced_backlog_beats_or_matches_cold_on_bursty_fleet() {
    // arrivals are identical in both worlds: single-launch (cold) costs
    // do not depend on the interlaunch flag, so the capacity the load is
    // scaled against is the same
    let warm_cfg = AccelConfig::paper();
    let cold_cfg = AccelConfig::paper().interlaunch(false);
    let cap = fleet_capacity_fps(&hetero_ts_fleet(&warm_cfg));
    assert!(
        (fleet_capacity_fps(&hetero_ts_fleet(&cold_cfg)) - cap).abs() < 1e-9,
        "cold/warm fleets must see identical offered load"
    );
    let arr = classed_arrivals(
        Arrival::Bursty {
            high: 2.0 * cap,
            burst_s: 0.2,
            gap_s: 0.3,
        },
        500,
        0.5,
        31,
    );
    let p99_of = |cfg: &AccelConfig| -> f64 {
        let mut r = Router::from_engines(hetero_ts_fleet(cfg), Policy::LeastLoaded)
            .with_load(LoadModel::Backlog);
        let comps = r.run_classed(&arr);
        assert_eq!(comps.len(), 500);
        percentile(&completion_latencies_ms(&comps), 0.99)
    };
    let cold = p99_of(&cold_cfg);
    let warm = p99_of(&warm_cfg);
    assert!(
        warm <= cold,
        "warm-queue p99 {warm:.2} ms lost to cold p99 {cold:.2} ms"
    );
    // and the warm world's engines really are warm/cold split
    let probe = SimEngine::new(0, &TINY, warm_cfg, 0.0);
    assert!(probe.steady_estimate(8) < probe.service_estimate(8));
}

/// Same comparison through the wall-clock executor path: SLO classes
/// flow end-to-end (Response carries the class; per-class metrics split)
/// and interactive keeps the shorter tail under a batch-heavy mix.
#[test]
fn wall_clock_slo_classes_flow_through_executor() {
    let server = Server::start_sim(
        &TINY,
        AccelConfig::paper(),
        0.2, // launch(8) sleeps ~tens of ms: deadline scales dominate jitter
        BatchPolicy {
            max_batch: 8,
            slo: Some(SloPolicy {
                interactive_max_wait: Duration::from_millis(10),
                batch_max_wait: Duration::from_millis(250),
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let (tx, rx) = mpsc::channel();
    let image = img(TINY_IMG, 0.0);
    // a batch-class backlog that will happily wait out its 250 ms window…
    for id in 0..3u64 {
        submit_classed(&server, id, image.clone(), Slo::Batch, &tx);
    }
    // …and one interactive request whose 10 ms deadline must flush the
    // whole 4-bucket early, carrying the batch requests along
    submit_classed(&server, 99, image.clone(), Slo::Interactive, &tx);
    let mut m = Metrics::default();
    for r in collect(&rx, 4) {
        assert_eq!(r.card, 0);
        m.record(&r);
    }
    server.shutdown().unwrap();
    assert_eq!(m.class_completed, [1, 3]);
    let p_i = m.class_percentile_ms(Slo::Interactive, 0.99);
    let p_b = m.class_percentile_ms(Slo::Batch, 0.99);
    // the batch backlog launched alongside the interactive flush instead
    // of waiting out its own 250 ms window: everyone lands well inside it
    assert!(p_i < 200.0, "interactive flushed late: {p_i:.1} ms");
    assert!(p_b < 250.0, "batch waited out its full window: {p_b:.1} ms");
}

#[test]
fn sim_demo_reports_full_metrics() {
    let m = run_demo_metrics_sim(
        &MICRO,
        AccelConfig::paper(),
        1.0,
        40,
        2_000.0,
        BatchPolicy::default(),
    )
    .unwrap();
    assert_eq!(m.completed, 40);
    assert_eq!(m.shed, 0);
    assert_eq!(m.batches.values().sum::<u64>(), 40);
    assert_eq!(m.occupancy_fracs.len(), 40);
    assert_eq!(m.queue_depths.len(), 40);
    assert!(m.percentile_ms(0.5) > 0.0);
    assert!(m.percentile_ms(0.95) >= m.percentile_ms(0.5));
    assert!(m.occupancy_mean() > 0.0 && m.occupancy_mean() <= 1.0);
    assert!(m.throughput() > 0.0);
    // the Display path (used by the CLI) renders every section
    let s = m.to_string();
    assert!(s.contains("occupancy") && s.contains("batch mix:"), "{s}");
}
