//! Randomised property tests over the simulator and coordinator
//! invariants (proptest is not in the vendored registry; `util::prng`
//! drives the cases — see DESIGN.md §5).

use swin_fpga::accel::mmu::Mmu;
use swin_fpga::accel::scu::Scu;
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::tiling::{pad_up, IntMat};
use swin_fpga::accel::AccelConfig;
use swin_fpga::approx::softmax::softmax_rows;
use swin_fpga::model::config::{BASE, MICRO, SMALL, TINY};
use swin_fpga::model::graph::{WorkloadGraph, TILE_K, TILE_M, TILE_N};
use swin_fpga::server::decompose;
use swin_fpga::util::prng::Rng;

#[test]
fn prop_gemm_padding_invariance_many_shapes() {
    let mmu = Mmu::new(AccelConfig::paper());
    let mut rng = Rng::new(11);
    for case in 0..60 {
        let rows = 1 + rng.below(80) as usize;
        let k = 1 + rng.below(96) as usize;
        let n = 1 + rng.below(96) as usize;
        let a = IntMat::from_vec(
            rows,
            k,
            (0..rows * k).map(|_| rng.range_i32(-800, 800)).collect(),
        );
        let b = IntMat::from_vec(
            k,
            n,
            (0..k * n).map(|_| rng.range_i32(-800, 800)).collect(),
        );
        let direct = mmu.gemm(&a, &b, 12);
        let padded = mmu
            .gemm(
                &a.pad_to(pad_up(rows, TILE_M), pad_up(k, TILE_K)),
                &b.pad_to(pad_up(k, TILE_K), pad_up(n, TILE_N)),
                12,
            )
            .crop(rows, n);
        assert_eq!(direct, padded, "case {case}: {rows}x{k}x{n}");
    }
}

#[test]
fn prop_softmax_rows_shift_invariant_and_bounded() {
    let mut rng = Rng::new(22);
    for _ in 0..40 {
        let width = 2 + rng.below(63) as usize;
        let rows = 1 + rng.below(4) as usize;
        let x: Vec<i32> = (0..rows * width)
            .map(|_| rng.range_i32(-2000, 2000))
            .collect();
        let shift = rng.range_i32(-1000, 1000);
        let shifted: Vec<i32> = x.iter().map(|v| v + shift).collect();
        let a = softmax_rows(&x, width);
        let b = softmax_rows(&shifted, width);
        assert_eq!(a, b, "shift invariance failed");
        // outputs in [0, 2^15), rows sum within approximation band
        for row in a.chunks_exact(width) {
            let s: i64 = row.iter().map(|&v| v as i64).sum();
            let sf = s as f64 / (1 << 15) as f64;
            assert!(row.iter().all(|&v| (0..=32767).contains(&v)));
            assert!((0.80..1.20).contains(&sf), "row sum {sf}");
        }
    }
}

#[test]
fn prop_fmu_grouped_never_slower_than_log2_plus_groups() {
    let scu = Scu::new(AccelConfig::paper());
    for n in 2..200usize {
        let c = scu.fmu_cycles(n);
        let lg = (n as f64).log2().ceil() as u64;
        assert!(c >= lg, "n={n}: {c} < ceil(log2)={lg}");
        assert!(c <= lg + 2, "n={n}: {c} too slow");
        // never worse than the linear scan; strictly better once trees
        // have any depth to exploit (tiny n can tie: n=3 → 2 vs 2)
        assert!(c <= scu.fmu_cycles_linear(n).max(1), "n={n}");
        if n >= 8 {
            assert!(c < scu.fmu_cycles_linear(n), "n={n}");
        }
    }
}

#[test]
fn prop_gemm_cycles_degenerate_free_and_monotone() {
    let mmu = Mmu::new(AccelConfig::paper());
    // degenerate shapes move no data: zero cycles, no pipeline fill
    let mut rng = Rng::new(44);
    for _ in 0..50 {
        let r = rng.below(100) as usize;
        let k = rng.below(100) as usize;
        let n = rng.below(100) as usize;
        assert_eq!(mmu.gemm_cycles(0, k, n), 0);
        assert_eq!(mmu.gemm_cycles(r, 0, n), 0);
        assert_eq!(mmu.gemm_cycles(r, k, 0), 0);
        // non-degenerate shapes always pay at least the pipeline fill
        let (r1, k1, n1) = (r + 1, k + 1, n + 1);
        let c = mmu.gemm_cycles(r1, k1, n1);
        assert!(c > 0, "{r1}x{k1}x{n1}");
        // growing any dimension never reduces the cycle count
        assert!(mmu.gemm_cycles(r1 + 49, k1, n1) >= c);
        assert!(mmu.gemm_cycles(r1, k1 + 32, n1) >= c);
        assert!(mmu.gemm_cycles(r1, k1, n1 + 32) >= c);
    }
}

#[test]
fn prop_sim_cycles_monotone_in_bandwidth() {
    // more effective bandwidth must never slow inference down
    let mut prev = u64::MAX;
    for eff in [0.5, 0.7, 0.9, 1.0] {
        let mut cfg = AccelConfig::paper();
        cfg.mem_efficiency = eff;
        let r = Simulator::new(&TINY, cfg).simulate_inference();
        assert!(r.total_cycles <= prev, "eff={eff}");
        prev = r.total_cycles;
    }
}

#[test]
fn prop_sim_cycles_monotone_in_pe_count() {
    let mut prev = u64::MAX;
    for pes in [8usize, 16, 32, 64] {
        let mut cfg = AccelConfig::paper();
        cfg.mmu_pes = pes;
        let r = Simulator::new(&TINY, cfg).simulate_inference();
        assert!(r.total_cycles <= prev, "pes={pes}");
        prev = r.total_cycles;
    }
}

#[test]
fn prop_macs_scale_with_variant_size() {
    let order = [&MICRO, &TINY, &SMALL, &BASE];
    let macs: Vec<u64> = order
        .iter()
        .map(|v| WorkloadGraph::build(v).total_macs())
        .collect();
    for w in macs.windows(2) {
        assert!(w[0] < w[1], "{macs:?}");
    }
}

#[test]
fn prop_decompose_covers_and_never_exceeds_plus_one_pad() {
    let sizes = [8usize, 4, 2, 1];
    let mut rng = Rng::new(33);
    for _ in 0..200 {
        let n = 1 + rng.below(64) as usize;
        let plan = decompose(n, &sizes);
        let covered: usize = plan.iter().sum();
        assert!(covered >= n, "n={n} plan={plan:?}");
        assert!(covered < n + 8, "n={n} over-padded {plan:?}");
        // plan is sorted descending (largest-fit)
        for w in plan.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}

#[test]
fn prop_schedule_totals_consistent() {
    for v in [&MICRO, &TINY, &SMALL, &BASE] {
        let r = Simulator::new(v, AccelConfig::paper()).simulate_inference();
        // critical path can't be shorter than either resource's total
        assert!(r.total_cycles >= r.mem_cycles.min(r.mmu_cycles));
        assert!(r.total_cycles <= r.mem_cycles + r.mmu_cycles + r.nonlinear_cycles);
        assert!(r.fps() > 0.0 && r.gops() > 0.0);
    }
}

#[test]
fn prop_invalid_fraction_increases_with_tile_width() {
    // ablation invariant: wider c_o → more Kᵀ padding waste
    let mut prev = 0.0;
    for co in [8usize, 16, 32, 64] {
        let u = swin_fpga::model::flops::invalid_fraction_block_with_co(96, 7, co);
        assert!(u >= prev - 1e-12, "co={co}: {u} < {prev}");
        prev = u;
    }
}
