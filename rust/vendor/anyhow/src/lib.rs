//! Offline subset of the `anyhow` API (the build environment vendors no
//! registry crates). Implements the pieces this workspace uses:
//!
//! * [`Error`] — a message plus a context chain; `{e}` prints the top
//!   message, `{e:#}` prints the whole chain colon-separated;
//! * [`Result<T>`] with `?`-conversion from any `std::error::Error`;
//! * the [`Context`] extension trait on `Result` and `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// Context-chained error value. The chain is stored top-first: the most
/// recently attached context prints first, mirroring anyhow.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Attach another layer of context (becomes the new top message).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: the error defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing thing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "missing thing");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert!(none.context("empty").is_err());
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing thing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert!(format!("{}", f(7).unwrap_err()).contains("x != 7"));
        assert!(f(3).is_err());
        let e = anyhow!("plain {}", 5);
        assert_eq!(format!("{e}"), "plain 5");
    }
}
