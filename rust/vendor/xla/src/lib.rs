//! Stub of the `xla` PJRT binding API used by `swin-fpga`'s [`runtime`]
//! layer (see that module's docs). The native PJRT CPU client is not
//! available in this build environment, so [`PjRtClient::cpu`] returns an
//! error and every downstream call is unreachable in practice; the types
//! exist so the runtime layer compiles unchanged and the serving stack
//! degrades to its simulated backend (`SimEngine`).
//!
//! Swapping this path dependency for the real `xla` crate re-enables the
//! PJRT path without touching `swin-fpga` source.

use std::fmt;

/// Error type matching the binding's surface (implements
/// `std::error::Error`, so `?` converts into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT backend not available in this build (xla stub crate); \
         rebuild with the real xla bindings to run AOT artifacts"
            .to_string(),
    ))
}

/// Element types the binding can move through literals.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (stub: never holds data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Device-side buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
    }

    #[test]
    fn error_converts_through_std_error() {
        let e: Box<dyn std::error::Error> = Box::new(Error("x".into()));
        assert!(e.to_string().contains("xla: x"));
    }
}
